"""Serving example: batched prefill + autoregressive decode with a KV cache.

    PYTHONPATH=src python examples/serve_decode.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.distributed.sharding import MeshAxes
from repro.launch.mesh import make_host_mesh
from repro.models import transformer as tf
from repro.models.params import materialize
from repro.configs.registry import _load


def main():
    _, cfg = _load("gemma-7b", smoke=True)    # reduced gemma-family config
    ax = MeshAxes(data=("data",), data_shards=1)
    mesh = make_host_mesh()
    params = materialize(tf.param_defs(cfg, ax), jax.random.key(0), cfg.dtype)

    B, prompt_len, gen_len = 4, 24, 16
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, prompt_len)),
                          jnp.int32)

    prefill = jax.jit(tf.make_prefill_step(cfg, ax))
    serve = jax.jit(tf.make_serve_step(cfg, ax), donate_argnums=(2,))

    with jax.set_mesh(mesh):
        logits, kvs = prefill(params, {"tokens": prompts})
        # pad the cache to prompt+gen and decode greedily
        caches = tuple(jnp.pad(t, ((0, 0), (0, 0), (0, gen_len), (0, 0), (0, 0)))
                       for t in kvs)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        outs = [tok]
        for i in range(gen_len - 1):
            logits, caches = serve(params, tok, caches,
                                   jnp.int32(prompt_len + i))
            tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
            outs.append(tok)
    gen = np.concatenate([np.asarray(t) for t in outs], axis=1)
    print("generated token ids (greedy):")
    print(gen)
    assert gen.shape == (B, gen_len)
    print("ok")


if __name__ == "__main__":
    main()
