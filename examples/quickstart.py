"""Quickstart: solve SSSP with SP-Async on a generated graph and validate.

    PYTHONPATH=src python examples/quickstart.py

Three modes are shown:
  1. single-source (the paper's setting) — a K=1 batch under the hood
  2. batched multi-source — ONE ``build_shards`` (partitioning, message
     routing, Trishla triangle enumeration, the dst-tiled Pallas edge
     layout) amortized over K queries that ride the same compiled solve
  3. the all-Pallas phase pipeline — every phase of the round (local
     relax, send pack, merge scatter) dispatched to its TPU kernel
     backend through the registry in ``core/phases.py``

The round is a phase PIPELINE: each phase resolves its backend from a
registry keyed by ``SsspConfig`` (``local_solver``, ``send_backend``,
``exchange``, ``merge_backend``, ``toka``), so backends compose freely
and a typo'd name raises ``ValueError`` at config construction — not
inside tracing. Pallas backends are bit-identical to the XLA ones.
"""
import numpy as np

from repro.core import SsspConfig, build_shards, solve_sim, solve_sim_batch
from repro.graph import rmat_graph, dijkstra_reference


def main():
    # 1. generate a ParMat-style graph (paper §IV.A: weights U[1,20))
    g = rmat_graph(scale=10, edge_factor=8, seed=0)
    print(f"graph: {g.n_vertices} vertices, {g.n_edges} edges")

    # 2. partition into 8 shards (paper §III.A: 1-D block). This is the
    #    expensive one-time step — everything it precomputes (static
    #    message slots, triangle candidates, the dst-tiled relax layout)
    #    is reused by EVERY query that follows.
    shards = build_shards(g, n_parts=8)

    # 3a. single-source solve with the full paper pipeline: Trishla pruning
    #     overlapped on idle shards, intra-shard Dijkstra-order settling,
    #     bucketed all_to_all exchange, ToKa2 token-ring termination
    cfg = SsspConfig(local_solver="delta", delta=6.0, toka="toka2",
                     prune_online=True)
    source = int(g.src[0])
    dist, stats = solve_sim(shards, source, cfg)

    ref = dijkstra_reference(g, source)
    ok = np.allclose(dist, ref, rtol=1e-5, atol=1e-4)
    print(f"single-source distances match Dijkstra: {ok}")
    print(f"rounds={int(stats.rounds)} relaxations={int(stats.relaxations)} "
          f"messages={int(stats.msgs_sent)} pruned_edges={int(stats.pruned_edges)}")
    assert ok

    # 3b. batched multi-source: K queries in one solve. The send payload
    #     becomes [K, P, C] but still moves in ONE collective per round
    #     (memory cost: 4 B x K x P x C per shard — batching multiplies
    #     payload bytes, not message count); per-query ToKa masks finished
    #     queries while stragglers run.
    sources = [int(s) for s in np.random.default_rng(1)
               .choice(g.n_vertices, size=8, replace=False)]
    dists, bstats = solve_sim_batch(shards, sources, cfg)

    # 4. validate every query against heap Dijkstra
    ok = all(np.allclose(dists[k], dijkstra_reference(g, s), rtol=1e-5,
                         atol=1e-4) for k, s in enumerate(sources))
    print(f"batched distances match Dijkstra ({len(sources)} queries): {ok}")
    print(f"rounds={int(bstats.rounds)} (slowest query) "
          f"per-query rounds={np.asarray(bstats.q_rounds).tolist()} "
          f"relaxations={np.asarray(bstats.q_relaxations).tolist()}")
    assert ok

    # 5. the all-Pallas pipeline: the relax kernel settles each shard,
    #     the slot-tiled send kernel packs the [K, P, C] payload, and the
    #     msg-tiled merge kernel scatters incoming messages — all over
    #     layouts step 2 precomputed (tx_*/mx_* next to rx_*). Interpret
    #     mode runs the kernels on CPU; set pallas_interpret=False on TPU.
    kcfg = SsspConfig(local_solver="pallas", send_backend="pallas",
                      merge_backend="pallas", toka="toka2")
    kdists, kstats = solve_sim_batch(shards, sources, kcfg)
    xcfg = SsspConfig(local_solver="pallas", toka="toka2")  # xla send/merge
    xdists, _ = solve_sim_batch(shards, sources, xcfg)
    identical = bool(np.array_equal(np.asarray(kdists), np.asarray(xdists)))
    print(f"pallas send/merge bit-identical to the XLA backends: "
          f"{identical}; rounds={int(kstats.rounds)}")
    assert identical


if __name__ == "__main__":
    main()
