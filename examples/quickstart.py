"""Quickstart: serve SSSP queries with an SP-Async session engine.

    PYTHONPATH=src python examples/quickstart.py

The public surface is ONE session object, ``SsspEngine``: build it once
over a graph (partitioning, static message routing, Trishla triangle
enumeration, the dst-tiled Pallas edge layouts — all amortized), then
stream queries at it. Sources are a TRACED input, so one compiled program
per K-bucket (powers of two) answers ARBITRARY source sets — the second
query batch of a given size never recompiles, on either backend.

Seven steps are shown:
  1. build the session (``SsspEngine.build``)
  2. solve query batches — watch the compile cache: cold once per bucket,
     then warm for every later batch of that shape
  3. stream ragged arrivals through ``submit``/``drain`` (coalesced into
     bucketed batches; a submission is never split)
  4. the all-Pallas phase pipeline as a second session over the SAME
     shards — every phase (local relax, send pack, merge scatter)
     dispatched to its TPU kernel backend, bit-identical to XLA — then
     the FUSED round (``round="fused"``): merge + relax fixpoint + send
     pack as ONE megakernel, 2 dispatches per round instead of 4, still
     bit-identical (``stats.n_dispatches`` shows the collapse)
  5. warm starts: ``precompute_landmarks`` + ``warm_start="landmark"``
     seeds every query with triangle-inequality upper bounds (repeated
     sources converge in ~1 round instead of re-propagating the wave),
     and the result LRU serves exact repeats with ZERO rounds — all
     bit-identical to the cold solves
  6. fault injection: the same solve under ``FaultPlan(drop=0.2)`` with
     anti-entropy resend and the ``toka3`` timeout detector — 20% of
     messages are dropped yet the distances come back BIT-IDENTICAL
     (the paper's monotone-merge robustness claim, exercised for real),
     with the stale-merge/resend counters showing the healing work
  7. the asynchronous mode: ``exchange="async"`` double-buffers the
     collective so round r's relax overlaps round r-1's delivery (no
     per-round barrier — the paper's headline). Rounds go UP (every
     merge lands one round late) but each round stops paying the
     synchronous barrier, which is the wall-time win at scale; the
     distances stay bit-identical, and ``overlap_fraction`` /
     ``stale_merges`` / ``bytes_moved`` quantify the trade
  8. scale: ``build_shards_stream`` partitions an edge-chunk ITERATOR
     (never materializing the whole graph) into ragged CSR-chunked
     layouts whose memory tracks actual — not worst-case — edge counts
     (``layout_bytes()`` reports measured bytes/edge vs the 16 B/edge
     CSR ideal), and the solve stays bit-identical to the dense layout

The legacy free functions (``solve_sim``, ``solve_sim_batch``,
``solve_shmap``, ``solve_shmap_batch``, ``build_shmap_solver``) still work
but are deprecated thin wrappers over a cached engine.
"""
import numpy as np

from repro.core import FaultPlan, SsspConfig, SsspEngine, build_shards
from repro.graph import rmat_graph, dijkstra_reference


def main():
    # 1. generate a ParMat-style graph (paper §IV.A: weights U[1,20)) and
    #    build the session: partition into 8 shards (paper §III.A: 1-D
    #    block) plus every static layout queries will reuse.
    g = rmat_graph(scale=10, edge_factor=8, seed=0)
    print(f"graph: {g.n_vertices} vertices, {g.n_edges} edges")
    shards = build_shards(g, n_parts=8)
    cfg = SsspConfig(local_solver="delta", delta=6.0, toka="toka2",
                     prune_online=True)
    engine = SsspEngine.build(shards, cfg)   # backend="sim"; "shmap" on a mesh

    # 2. solve: a single source is a K=1 batch. The first batch of a bucket
    #    compiles; every later batch of that shape is warm.
    source = int(g.src[0])
    res = engine.solve(source)
    ref = dijkstra_reference(g, source)
    ok = np.allclose(res.dist[0], ref, rtol=1e-5, atol=1e-4)
    print(f"single-source distances match Dijkstra: {ok}")
    print(f"rounds={int(res.stats.rounds)} "
          f"relaxations={int(res.stats.relaxations)} "
          f"cold: wall={res.wall_s:.2f}s (compile {res.compile_s:.2f}s) "
          f"bucket K={res.bucket_k}")
    assert ok

    # multi-source: 6 queries pad up to the K=8 bucket; padded rows start
    # converged and never relax, send, or count in any statistic. The
    # [K, P, C] payload still moves in ONE collective per round.
    rng = np.random.default_rng(1)
    sources = [int(s) for s in rng.choice(g.n_vertices, size=6, replace=False)]
    batch = engine.solve(sources)
    ok = all(np.allclose(batch.dist[k], dijkstra_reference(g, s), rtol=1e-5,
                         atol=1e-4) for k, s in enumerate(sources))
    print(f"batched distances match Dijkstra ({len(sources)} queries, "
          f"bucket K={batch.bucket_k}): {ok}")
    print(f"per-query rounds={batch.q_rounds.tolist()} "
          f"relaxations={batch.q_relaxations.tolist()}")
    assert ok

    # same bucket, new sources -> NO recompile (sources are traced inputs)
    warm = engine.solve([int(s) for s in
                         rng.choice(g.n_vertices, size=8, replace=False)])
    print(f"warm solve, same bucket: compiled={warm.compiled} "
          f"wall={warm.wall_s:.3f}s "
          f"({batch.wall_s / warm.wall_s:.0f}x faster than that bucket's "
          f"cold solve)")
    assert not warm.compiled
    print(f"compiled programs by bucket: {engine.trace_counts}")

    # 3. streaming arrivals: submit now, drain coalesces into bucketed
    #    batches (here 1+2+1 queries ride one K=4 program together).
    h1 = engine.submit(source)
    h2 = engine.submit(sources[:2])
    engine.submit(sources[2])
    engine.drain()
    ok = np.allclose(h1.result().dist[0], ref, rtol=1e-5, atol=1e-4)
    print(f"streamed queries: {ok}; h2 rode bucket "
          f"K={h2.result().bucket_k} with {len(h2.sources)} sources")
    assert ok

    # 4. the all-Pallas pipeline as a second session over the SAME shards:
    #    relax kernel settles each shard, the slot-tiled send kernel packs
    #    the payload, the msg-tiled merge kernel scatters incoming — over
    #    layouts build_shards precomputed (tx_*/mx_* next to rx_*).
    #    Interpret mode runs the kernels on CPU; pallas_interpret=False on
    #    real TPUs. Bit-identical to the XLA backends.
    kengine = SsspEngine.build(shards, SsspConfig(
        local_solver="pallas", send_backend="pallas", merge_backend="pallas",
        toka="toka2"))
    xengine = SsspEngine.build(shards, SsspConfig(
        local_solver="pallas", toka="toka2"))          # xla send/merge
    kres = kengine.solve(sources)
    xres = xengine.solve(sources)
    identical = bool(np.array_equal(kres.dist, xres.dist))
    print(f"pallas send/merge bit-identical to the XLA backends: "
          f"{identical}; rounds={int(kres.stats.rounds)}")
    assert identical

    # fused round: the three data-plane phases share one dst-tiled tiling,
    # so ``round="fused"`` composes them into a single ``pallas_call`` —
    # the per-round dispatch count drops from 4 (local/send/exchange/merge)
    # to 2 (megakernel + exchange), which is the round cost at µs-scale
    # phases. Same messages, same rounds, same bits.
    fused_eng = SsspEngine.build(shards, SsspConfig(round="fused",
                                                    toka="toka2"))
    fres = fused_eng.solve(sources)
    assert np.array_equal(fres.dist, xres.dist)
    print(f"fused megakernel round bit-identical: dispatches/solve "
          f"{int(xres.stats.n_dispatches)} (staged) -> "
          f"{int(fres.stats.n_dispatches)} (fused) over "
          f"{int(fres.stats.rounds)} rounds")

    # 5. warm starts: solve a few landmark pivots ONCE, then serve. The
    #    warm_init stage seeds each query's distances with the
    #    triangle-inequality bound min_l(land[l, src] + land[l, v]) — an
    #    upper bound, so the monotone pipeline reaches the same fixpoint
    #    bit-for-bit, just from a much closer start. A repeated source's
    #    seed IS its solved fixpoint, so it converges in ~1 round; an
    #    exact repeat within the result LRU does not solve at all.
    wengine = SsspEngine.build(shards, SsspConfig(
        local_solver="delta", delta=6.0, warm_start="landmark",
        prune_online=True))
    pivots = [int(s) for s in rng.choice(g.n_vertices, size=4, replace=False)]
    lm = wengine.precompute_landmarks(pivots)
    print(f"landmark cache: {lm.n_landmarks} pivots, "
          f"{lm.nbytes_per_shard} B/shard")
    cold = engine.solve(pivots[0])                  # cold reference engine
    warm = wengine.solve(pivots[0])                 # landmark-seeded solve
    assert np.array_equal(cold.dist, warm.dist)
    print(f"repeated source, landmark-seeded: rounds "
          f"{int(cold.stats.rounds)} -> {int(warm.stats.rounds)}, "
          f"bit-identical, warm_started={warm.warm_started}")

    # exact repeats can skip the pipeline entirely: a result LRU keyed by
    # (source, graph_epoch) serves them with zero rounds.
    cache_eng = SsspEngine.build(shards, SsspConfig(
        local_solver="delta", delta=6.0), result_cache=32)
    first = cache_eng.solve(sources[:2])
    hit = cache_eng.solve(sources[:2])
    assert hit.cache_hits == 2 and int(hit.stats.rounds) == 0
    assert np.array_equal(hit.dist, first.dist)
    print(f"exact repeat from the result cache: zero rounds, "
          f"{hit.wall_s * 1e3:.2f}ms for {len(first.sources)} queries")

    # 6. fault injection: drop 20% of all exchanged messages, heal them
    #    with anti-entropy resends, terminate with the paper's timeout
    #    heuristic (toka3). The scatter-min merge is monotone and
    #    idempotent, so the faulted run reaches the SAME fixpoint — more
    #    rounds, identical bits. The engine's fixpoint certificate (one
    #    extra relax round) backs status="converged" with proof; with
    #    resend_period=0 the same drops would leave status="degraded" and
    #    the result barred from every cache.
    finj = SsspEngine.build(shards, SsspConfig(
        local_solver="delta", delta=6.0, toka="toka3", prune_online=True,
        faults=FaultPlan(drop=0.2, seed=0, resend_period=4)))
    fr = finj.solve(sources)
    assert np.array_equal(fr.dist, batch.dist)
    assert fr.status == "converged"
    print(f"20% message drop, healed: status={fr.status}, distances "
          f"bit-identical to the fault-free solve")
    print(f"  rounds {int(batch.stats.rounds)} -> {int(fr.stats.rounds)}, "
          f"stale_merges={int(fr.stats.stale_merges)}, "
          f"resends={int(fr.stats.resends)} "
          f"(+{int(fr.stats.msgs_sent) - int(batch.stats.msgs_sent)} msgs "
          f"healing overhead)")

    # 7. asynchronous mode (P=8): defer the exchange — the round never
    #    barriers on the collective. Each merge lands one round late, so
    #    rounds go UP; in exchange every round's wall time drops from
    #    compute + tree-barrier to ~max(compute, neighbor-hop) on a real
    #    transport (the lock-step sim here can only COUNT the overlap, not
    #    cash it — benchmarks/sssp_bench.py prices it with the alpha-beta
    #    model). Bit-identical distances, certified, same certificate.
    async_eng = SsspEngine.build(shards, SsspConfig(
        local_solver="delta", delta=6.0, toka="toka2", prune_online=True,
        exchange="async"))
    ar = async_eng.solve(sources)
    assert np.array_equal(ar.dist, batch.dist)
    assert ar.status == "converged"
    print(f"async exchange at P=8: rounds {int(batch.stats.rounds)} -> "
          f"{int(ar.stats.rounds)} (merges lag one round), distances "
          f"bit-identical")
    print(f"  overlap_fraction={ar.overlap_fraction:.2f} "
          f"({int(ar.stats.overlap_rounds)} rounds had payload in flight "
          f"during compute), stale_merges="
          f"{int(np.asarray(ar.stats.stale_merges).sum())}, "
          f"bytes_moved={int(ar.stats.bytes_moved)} — on hardware the "
          f"barrier-free rounds are the speedup; here they are the metric")

    # 8. scale: stream-build ragged CSR-chunked shards from edge chunks.
    #    The iterator is the input — a 10M-edge RMAT graph partitions in
    #    chunk-sized memory (benchmarks/sssp_bench.py --scale-full runs
    #    it) — and the ragged layouts drop dense's worst-case-chunks-on-
    #    every-tile padding while keeping the solve bit-identical.
    from repro.core import build_shards_stream
    from repro.graph import edge_chunks_of
    # (enumerate_triangles matches the dense session above so Trishla's
    # online pruning takes identical decisions; it defaults OFF for the
    # streaming builder, whose target graphs are too big for it)
    rsh = build_shards_stream(edge_chunks_of(g), g.n_vertices, 8,
                              enumerate_triangles=True)
    rlb, dlb = rsh.layout_bytes(), shards.layout_bytes()
    reng = SsspEngine.build(rsh, SsspConfig(local_solver="delta", delta=6.0,
                                            toka="toka2", prune_online=True))
    rres = reng.solve(sources)
    assert np.array_equal(rres.dist, batch.dist)
    print(f"ragged stream-built shards: {rlb['bytes_per_edge']:.1f} B/edge "
          f"measured (dense {dlb['bytes_per_edge']:.1f}, CSR ideal "
          f"{rlb['ideal_bytes_per_edge']:.0f}), distances bit-identical")


if __name__ == "__main__":
    main()
