"""Quickstart: solve SSSP with SP-Async on a generated graph and validate.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import SsspConfig, build_shards, solve_sim
from repro.graph import rmat_graph, dijkstra_reference


def main():
    # 1. generate a ParMat-style graph (paper §IV.A: weights U[1,20))
    g = rmat_graph(scale=10, edge_factor=8, seed=0)
    print(f"graph: {g.n_vertices} vertices, {g.n_edges} edges")

    # 2. partition into 8 shards (paper §III.A: 1-D block)
    shards = build_shards(g, n_parts=8)

    # 3. solve with the full paper pipeline: Trishla pruning overlapped on
    #    idle shards, intra-shard Dijkstra-order settling, bucketed
    #    all_to_all exchange, ToKa2 token-ring termination
    cfg = SsspConfig(local_solver="delta", delta=6.0, toka="toka2",
                     prune_online=True)
    source = int(g.src[0])
    dist, stats = solve_sim(shards, source, cfg)

    # 4. validate against heap Dijkstra
    ref = dijkstra_reference(g, source)
    ok = np.allclose(dist, ref, rtol=1e-5, atol=1e-4)
    print(f"distances match Dijkstra: {ok}")
    print(f"rounds={int(stats.rounds)} relaxations={int(stats.relaxations)} "
          f"messages={int(stats.msgs_sent)} pruned_edges={int(stats.pruned_edges)}")
    assert ok


if __name__ == "__main__":
    main()
