"""End-to-end driver: train a ~100M-param dense LM for a few hundred steps
with checkpointing, on whatever devices exist.

    PYTHONPATH=src python examples/train_lm.py --steps 300

The config is a scaled deepseek-7b family member (~100M params). On a real
TPU pod, swap make_host_mesh for make_production_mesh and point --ckpt-dir
at durable storage — everything else is identical.
"""
import argparse

import jax

from repro.checkpoint import CheckpointManager
from repro.data import TokenStream
from repro.distributed.sharding import MeshAxes
from repro.launch.mesh import make_host_mesh
from repro.models import transformer as tf
from repro.models.params import materialize, n_params as count_params
from repro.optim import AdamWConfig
from repro.optim.adamw import adamw_init


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--steps", type=int, default=300)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=256)
    p.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    args = p.parse_args()

    cfg = tf.TransformerConfig(
        name="lm-100m", n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
        d_ff=2048, vocab_size=32000, dtype="float32", attn_chunk=128)
    ax = MeshAxes(data=("data",), data_shards=1)
    mesh = make_host_mesh()

    defs = tf.param_defs(cfg, ax)
    print(f"params: {count_params(defs) / 1e6:.1f}M")
    params = materialize(defs, jax.random.key(0), cfg.dtype)
    opt = adamw_init(params)
    step = jax.jit(tf.make_train_step(cfg, ax, AdamWConfig(lr=3e-4)),
                   donate_argnums=(0, 1))
    data = iter(TokenStream(args.batch, args.seq, cfg.vocab_size))
    mgr = CheckpointManager(args.ckpt_dir, keep=2)

    start = 0
    restored = mgr.restore((params, opt)) if mgr.latest() else (None, None)
    if restored[0] is not None:
        (params, opt), start = restored
        print(f"resumed at step {start}")

    with jax.set_mesh(mesh):
        for s in range(start, args.steps):
            params, opt, m = step(params, opt, next(data))
            if (s + 1) % 20 == 0:
                print(f"step {s+1}: loss={float(m['loss']):.4f}")
            if (s + 1) % 100 == 0:
                mgr.save(s + 1, (params, opt))
    print("done")


if __name__ == "__main__":
    main()
