"""GNN example: minibatch GraphSAGE-style training of GAT with the real
neighbor sampler (the minibatch_lg pattern at CPU scale).

    PYTHONPATH=src python examples/gnn_products.py

Uses the REAL ogbn-products graph when a local extract exists under
``data/ogbn_products/`` (see ``repro.graph.ogbn_products_graph`` for how to
stage one — this container is offline and never downloads), otherwise a
products-like R-MAT stand-in.
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.distributed.sharding import MeshAxes
from repro.graph import ogbn_products_graph, rmat_graph
from repro.graph.sampler import NeighborSampler
from repro.launch.mesh import make_host_mesh
from repro.models import gnn
from repro.models.params import materialize
from repro.optim import AdamWConfig
from repro.optim.adamw import adamw_init


def main():
    try:
        g = ogbn_products_graph()
        print(f"ogbn-products: {g.n_vertices} vertices, {g.n_edges} edges")
    except FileNotFoundError:
        # products-like graph at CPU scale
        g = rmat_graph(scale=12, edge_factor=8, seed=0)
    n, d_feat, n_classes = g.n_vertices, 32, 16
    rng = np.random.default_rng(0)
    feats = rng.standard_normal((n, d_feat)).astype(np.float32)
    labels = rng.integers(0, n_classes, n).astype(np.int32)

    sampler = NeighborSampler(g, fanouts=(10, 5), seed=0)
    cfg = gnn.GatConfig(n_layers=2, d_hidden=16, n_heads=4, d_in=d_feat,
                        n_classes=n_classes)
    ax = MeshAxes(data=("data",), data_shards=1)
    mesh = make_host_mesh()
    params = materialize(gnn.gat_param_defs(cfg, ax), jax.random.key(0))
    opt = adamw_init(params)
    step = jax.jit(gnn.make_gnn_train_step(gnn.gat_loss, cfg, ax,
                                           AdamWConfig(lr=3e-3)))

    B = 64
    max_n = sampler.max_nodes(B)
    with jax.set_mesh(mesh):
        for s in range(20):
            seeds = rng.choice(n, B, replace=False)
            nodes, src, dst, n_real = sampler.sample(seeds)
            sub_feat = np.zeros((max_n, d_feat), np.float32)
            sub_lab = np.full(max_n, -1, np.int32)       # -1 = unlabeled pad
            sub_feat[:n_real] = feats[nodes[:n_real]]
            sub_lab[:B] = labels[seeds]                  # loss on seeds only
            batch = dict(
                node_feat=jnp.asarray(sub_feat),
                edge_src=jnp.asarray(src, jnp.int32),
                edge_dst=jnp.asarray(dst, jnp.int32),
                labels=jnp.asarray(sub_lab))
            params, opt, m = step(params, opt, batch)
            if (s + 1) % 5 == 0:
                print(f"step {s+1}: loss={float(m['loss']):.4f}")
    print("ok")


if __name__ == "__main__":
    main()
