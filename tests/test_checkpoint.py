"""Checkpoint substrate: atomic commit, keep-K pruning, elastic restore."""
import os

import numpy as np
import jax
import jax.numpy as jnp

from repro.checkpoint import (CheckpointManager, latest_step,
                              restore_checkpoint, save_checkpoint)


def _tree(seed):
    k = jax.random.key(seed)
    return {"w": jax.random.normal(k, (8, 16)),
            "nested": {"b": jnp.arange(5, dtype=jnp.float32)},
            "step": jnp.int32(seed)}


def test_save_restore_roundtrip(tmp_path):
    t = _tree(3)
    save_checkpoint(str(tmp_path), 3, t)
    assert latest_step(str(tmp_path)) == 3
    got = restore_checkpoint(str(tmp_path), 3, t)
    for a, b in zip(jax.tree_util.tree_leaves(t),
                    jax.tree_util.tree_leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_manager_keeps_newest_k(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree(s))
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(tmp_path))
    assert steps == [3, 4]
    got, s = mgr.restore(_tree(0))
    assert s == 4
    assert int(got["step"]) == 4


def test_partial_write_is_invisible(tmp_path):
    """A .tmp directory (simulated crash mid-write) is never 'latest'."""
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(5, _tree(5))
    os.makedirs(os.path.join(tmp_path, "step_00000009.tmp"))
    assert mgr.latest() == 5


def test_restore_casts_dtype(tmp_path):
    t = {"w": jnp.ones((4,), jnp.float32)}
    save_checkpoint(str(tmp_path), 1, t)
    target = {"w": jax.ShapeDtypeStruct((4,), jnp.bfloat16)}
    got = restore_checkpoint(str(tmp_path), 1, target)
    assert got["w"].dtype == jnp.bfloat16


def test_train_loop_resume(tmp_path):
    """Crash/restart: a resumed run continues from the saved step."""
    from repro.launch.train import main
    ckpt = str(tmp_path / "ck")
    main(["--arch", "deepseek-7b", "--smoke", "--steps", "6", "--batch", "2",
          "--seq", "16", "--ckpt-dir", ckpt, "--ckpt-every", "3",
          "--log-every", "100"])
    assert latest_step(ckpt) == 6
    # resume: should do steps 7..8 only
    main(["--arch", "deepseek-7b", "--smoke", "--steps", "8", "--batch", "2",
          "--seq", "16", "--ckpt-dir", ckpt, "--ckpt-every", "100",
          "--log-every", "100"])
