"""Pallas dst-tiled relax kernel as the production local solver.

Three layers of equivalence, binding the kernel to the system:
  1. masked single sweep  == the jnp solver sweep (frontier + pruned + count)
  2. fused fixpoint kernel == local_fixpoint_bellman on one shard
  3. local_solver="pallas" == dijkstra_reference end-to-end (sim and shmap,
     several partition counts, R-MAT and road-grid graphs)
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import jax.numpy as jnp
import pytest

from _hyp import given, settings, strategies as st
from repro.core import SsspConfig, build_shards, solve_sim
from repro.core.local_solver import (_sweep, local_fixpoint_bellman,
                                     local_fixpoint_pallas)
from repro.graph import (dijkstra_reference, random_graph, rmat_graph,
                         road_grid_graph)
from repro.graph.structure import graph_to_numpy
from repro.kernels.relax import (build_dst_tiled_layout, relax_masked_pallas,
                                 relax_fixpoint_pallas)

rng = np.random.default_rng(7)
ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _random_state(n, m, seed):
    g = random_graph(n, m, seed=seed)
    src, dst, w = graph_to_numpy(g)
    dist = rng.uniform(0, 50, n).astype(np.float32)
    dist[rng.random(n) < 0.3] = np.inf
    frontier = rng.random(n) < 0.5
    pruned = rng.random(len(src)) < 0.2
    return src, dst, w, dist, frontier, pruned


def _tiled(src, dst, w, n, vb, eb, pruned):
    src_t, w_t, dr_t, eid_t, bp = build_dst_tiled_layout(
        src, dst, w, n, vb=vb, eb=eb, with_eid=True)
    pruned_t = jnp.take(jnp.asarray(pruned, jnp.int32), eid_t, mode="fill",
                        fill_value=0)
    return src_t, w_t, dr_t, pruned_t, bp


def _pad(x, bp, fill):
    return jnp.asarray(np.pad(np.asarray(x, np.float32), (0, bp - len(x)),
                              constant_values=fill))


# ------------------------------------------------- masked single sweep ----

@pytest.mark.parametrize("n,m,vb,eb,seed", [
    (100, 400, 128, 128, 0), (500, 3000, 128, 256, 1), (257, 900, 128, 512, 2),
])
def test_masked_sweep_matches_solver_sweep(n, m, vb, eb, seed):
    src, dst, w, dist, frontier, pruned = _random_state(n, m, seed)
    ref_dist, _, ref_n = _sweep(jnp.asarray(dist), jnp.asarray(frontier),
                                jnp.asarray(src, jnp.int32),
                                jnp.asarray(dst, jnp.int32), jnp.asarray(w),
                                jnp.asarray(pruned))
    src_t, w_t, dr_t, pruned_t, bp = _tiled(src, dst, w, n, vb, eb, pruned)
    out, nrel = relax_masked_pallas(
        _pad(dist, bp, np.inf), _pad(frontier, bp, 0.0),
        src_t, w_t, dr_t, pruned_t, vb=vb, eb=eb)
    np.testing.assert_allclose(np.asarray(out)[:n], np.asarray(ref_dist),
                               rtol=1e-6, atol=1e-6)
    assert int(nrel) == int(ref_n)


# -------------------------------------------------- fused fixpoint kernel ----

@pytest.mark.parametrize("n,m,sweeps,seed", [
    (120, 500, 1, 3), (120, 500, 4, 4), (300, 1800, 8, 5), (64, 90, 16, 6),
])
def test_fixpoint_kernel_matches_bellman(n, m, sweeps, seed):
    """Chained fixpoint calls (residual-frontier loop) reach the bellman
    fixpoint regardless of how many sweeps are fused per call."""
    src, dst, w, dist, frontier, pruned = _random_state(n, m, seed)
    ref = local_fixpoint_bellman(
        jnp.asarray(dist), jnp.asarray(frontier), jnp.asarray(src, jnp.int32),
        jnp.asarray(dst, jnp.int32), jnp.asarray(w), jnp.asarray(pruned),
        max_iters=10_000)

    vb, eb = 128, 256
    src_t, w_t, dr_t, pruned_t, bp = _tiled(src, dst, w, n, vb, eb, pruned)
    d, f = _pad(dist, bp, np.inf), _pad(frontier, bp, 0.0)
    for _ in range(200):
        d, f, _ = relax_fixpoint_pallas(d, f, src_t, w_t, dr_t, pruned_t,
                                        vb=vb, eb=eb, n_sweeps=sweeps)
        if not bool(jnp.any(f > 0)):
            break
    np.testing.assert_allclose(np.asarray(d)[:n], np.asarray(ref.dist),
                               rtol=1e-6, atol=1e-6)


def test_local_fixpoint_pallas_entry():
    """The solver-facing wrapper (padding + pruned gather + while_loop)."""
    src, dst, w, dist, frontier, pruned = _random_state(200, 900, 8)
    ref = local_fixpoint_bellman(
        jnp.asarray(dist), jnp.asarray(frontier), jnp.asarray(src, jnp.int32),
        jnp.asarray(dst, jnp.int32), jnp.asarray(w), jnp.asarray(pruned),
        max_iters=10_000)
    lay = build_dst_tiled_layout(src, dst, w, 200, vb=128, eb=256,
                                 with_eid=True)
    res = local_fixpoint_pallas(jnp.asarray(dist), jnp.asarray(frontier),
                                jnp.asarray(pruned), lay[:4], vb=128,
                                max_iters=10_000, sweeps=4)
    np.testing.assert_allclose(np.asarray(res.dist), np.asarray(ref.dist),
                               rtol=1e-6, atol=1e-6)
    assert bool(res.changed) == bool(ref.changed)


# --------------------------------------------------- end-to-end (sim) ----

def _check_sim(g, P, cfg, source=0):
    sh = build_shards(g, P)
    dist, stats = solve_sim(sh, source, cfg)
    ref = dijkstra_reference(g, source)
    np.testing.assert_allclose(dist, ref, rtol=1e-5, atol=1e-4)
    return stats


@settings(max_examples=6, deadline=None)
@given(scale=st.integers(5, 8), ef=st.integers(2, 8), p=st.integers(1, 8),
       seed=st.integers(0, 1000))
def test_pallas_solver_rmat_property(scale, ef, p, seed):
    g = rmat_graph(scale=scale, edge_factor=ef, seed=seed)
    _check_sim(g, p, SsspConfig(local_solver="pallas"))


@settings(max_examples=4, deadline=None)
@given(side=st.integers(6, 16), p=st.integers(1, 8), seed=st.integers(0, 1000))
def test_pallas_solver_road_property(side, p, seed):
    g = road_grid_graph(side=side, seed=seed)
    _check_sim(g, p, SsspConfig(local_solver="pallas"))


@pytest.mark.parametrize("p", [1, 4, 8])
def test_pallas_equals_bellman_stats(p):
    """Same distances AND same message/round trajectory as bellman — the
    pallas solver changes the local math, not the protocol."""
    g = rmat_graph(scale=7, edge_factor=6, seed=5)
    s_b = _check_sim(g, p, SsspConfig(local_solver="bellman"))
    s_p = _check_sim(g, p, SsspConfig(local_solver="pallas"))
    assert int(s_b.rounds) == int(s_p.rounds)
    assert int(s_b.msgs_sent) == int(s_p.msgs_sent)


def test_pallas_falls_back_without_layout():
    g = random_graph(150, 600, seed=9)
    sh = build_shards(g, 4, relax_layout=False)
    assert not sh.has_relax_layout
    dist, _ = solve_sim(sh, 0, SsspConfig(local_solver="pallas"))
    ref = dijkstra_reference(g, 0)
    np.testing.assert_allclose(dist, ref, rtol=1e-5, atol=1e-4)


def test_layout_built_once_in_shards():
    """build_shards carries the stacked dst-tiled layout (no per-solve
    relayout): shapes line up with the kernel contract."""
    g = random_graph(200, 800, seed=10)
    sh = build_shards(g, 4)
    P = sh.n_parts
    assert sh.rx_src.shape[0] == P
    assert sh.rx_src.shape == sh.rx_w.shape == sh.rx_dstrel.shape == sh.rx_eid.shape
    n_vtiles = sh.rx_src.shape[1]
    assert n_vtiles * sh.rx_vb >= sh.block
    # every real local edge appears exactly once in the tiled layout
    for p in range(P):
        eids = np.asarray(sh.rx_eid[p]).ravel()
        real = np.sort(eids[eids < sh.e_loc])
        valid = np.isfinite(np.asarray(sh.loc_w[p]))
        np.testing.assert_array_equal(real, np.nonzero(valid)[0])


# ------------------------------------------- acceptance matrix (slow) ----

_BENCH_PROG = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.environ["JAX_PLATFORMS"] = "cpu"
    import numpy as np
    from repro import compat
    from repro.core import SsspConfig, build_shards, solve_shmap, solve_sim
    from repro.graph import rmat_graph, road_grid_graph, dijkstra_reference

    graphs = {
        "graph1-like": rmat_graph(scale=11, edge_factor=2, seed=1),
        "graph2-like": road_grid_graph(side=48, seed=2),
        "graph3-like": rmat_graph(scale=9, edge_factor=24, seed=3),
    }
    cfg = SsspConfig(local_solver="pallas", prune_online=False)
    for name, g in graphs.items():
        source = int(g.src[0])
        ref = dijkstra_reference(g, source)
        for p in (1, 4, 8):
            sh = build_shards(g, p, enumerate_triangles=False)
            d, _ = solve_sim(sh, source, cfg)
            assert np.allclose(d, ref, 1e-5, 1e-4), ("sim", name, p)
            mesh = compat.make_mesh((p,), ("d",))
            d, _ = solve_shmap(sh, source, cfg, mesh, ("d",))
            assert np.allclose(d, ref, 1e-5, 1e-4), ("shmap", name, p)
    print("PALLAS MATRIX OK")
""")


@pytest.mark.slow
def test_pallas_bench_graph_matrix():
    """Acceptance: pallas solver matches Dijkstra on all three BENCH_GRAPHS
    at P in {1, 4, 8}, in both sim and shmap backends."""
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    out = subprocess.run([sys.executable, "-c", _BENCH_PROG], env=env,
                         capture_output=True, text=True, timeout=1200)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "PALLAS MATRIX OK" in out.stdout
