"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret mode."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.graph import random_graph
from repro.graph.structure import graph_to_numpy
from repro.kernels.relax import (relax_pallas, relax_ref,
                                 build_dst_tiled_layout)
from repro.kernels.flash_attention import flash_attention, attention_ref
from repro.kernels.embedding_bag import embedding_bag, embedding_bag_ref

rng = np.random.default_rng(0)


# ---------------------------------------------------------------- relax ----

@pytest.mark.parametrize("n,m,vb,eb", [
    (100, 400, 128, 128), (500, 3000, 128, 256), (257, 900, 128, 512),
    (64, 80, 128, 128),
])
def test_relax_shapes(n, m, vb, eb):
    g = random_graph(n, m, seed=n + m)
    src, dst, w = graph_to_numpy(g)
    dist = rng.uniform(0, 50, n).astype(np.float32)
    dist[rng.random(n) < 0.3] = np.inf
    src_t, w_t, dstrel_t, block_pad = build_dst_tiled_layout(src, dst, w, n,
                                                             vb=vb, eb=eb)
    dist_pad = jnp.asarray(np.concatenate(
        [dist, np.full(block_pad - n, np.inf, np.float32)]))
    out = relax_pallas(dist_pad, src_t, w_t, dstrel_t, vb=vb, eb=eb)
    ref = relax_ref(jnp.asarray(dist), jnp.asarray(src), jnp.asarray(dst),
                    jnp.asarray(w))
    np.testing.assert_allclose(np.asarray(out)[:n], np.asarray(ref),
                               rtol=1e-6, atol=1e-6)


def test_relax_all_inf_noop():
    g = random_graph(80, 200, seed=9)
    src, dst, w = graph_to_numpy(g)
    src_t, w_t, dstrel_t, bp = build_dst_tiled_layout(src, dst, w, 80)
    dist_pad = jnp.full((bp,), jnp.inf, jnp.float32)
    out = relax_pallas(dist_pad, src_t, w_t, dstrel_t)
    assert np.isinf(np.asarray(out)).all()


# ------------------------------------------------------- flash attention ----

@pytest.mark.parametrize("B,Hq,Hkv,Sq,Skv,D,causal,dtype,bq,bk", [
    (2, 4, 4, 128, 128, 64, True, jnp.float32, 64, 64),
    (2, 4, 2, 96, 160, 64, True, jnp.float32, 32, 64),     # GQA + pads
    (1, 8, 1, 64, 64, 32, False, jnp.float32, 64, 32),     # MQA bidir
    (2, 4, 4, 128, 128, 64, True, jnp.bfloat16, 64, 64),
    (1, 2, 2, 33, 77, 16, True, jnp.float32, 16, 32),      # ragged pads
])
def test_flash_vs_ref(B, Hq, Hkv, Sq, Skv, D, causal, dtype, bq, bk):
    q = jnp.asarray(rng.standard_normal((B, Hq, Sq, D)), dtype)
    k = jnp.asarray(rng.standard_normal((B, Hkv, Skv, D)), dtype)
    v = jnp.asarray(rng.standard_normal((B, Hkv, Skv, D)), dtype)
    out = flash_attention(q, k, v, causal=causal, block_q=bq, block_k=bk)
    ref = attention_ref(q, k, v, causal=causal)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    err = float(jnp.max(jnp.abs(out.astype(jnp.float32)
                                - ref.astype(jnp.float32))))
    assert err < tol, err


def test_flash_decode_offset():
    B, H, Hkv, Skv, D = 1, 4, 2, 192, 64
    q = jnp.asarray(rng.standard_normal((B, H, 1, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, Hkv, Skv, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, Hkv, Skv, D)), jnp.float32)
    out = flash_attention(q, k, v, causal=True, q_offset=Skv - 1, block_q=1)
    ref = attention_ref(q, k, v, causal=True, q_offset=Skv - 1)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


# --------------------------------------------------------- embedding bag ----

@pytest.mark.parametrize("V,D,B,L,mode,dtype", [
    (100, 16, 16, 4, "sum", jnp.float32),
    (64, 32, 10, 7, "mean", jnp.float32),
    (128, 8, 8, 3, "sum", jnp.bfloat16),
    (32, 128, 24, 1, "sum", jnp.float32),
])
def test_embedding_bag_vs_ref(V, D, B, L, mode, dtype):
    table = jnp.asarray(rng.standard_normal((V, D)), dtype)
    idx = jnp.asarray(rng.integers(0, V + 1, (B, L)), jnp.int32)
    out = embedding_bag(table, idx, mode=mode)
    ref = embedding_bag_ref(table, idx, mode=mode)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-5
    err = float(jnp.max(jnp.abs(out.astype(jnp.float32)
                                - ref.astype(jnp.float32))))
    assert err < tol


def test_embedding_bag_all_padding():
    table = jnp.ones((16, 8), jnp.float32)
    idx = jnp.full((4, 3), 16, jnp.int32)        # all sentinel
    out = embedding_bag(table, idx)
    assert np.abs(np.asarray(out)).max() == 0.0
