"""Kernel <-> system integration: the Pallas relax kernel computes the same
sweep as the SSSP local solver's jnp path on REAL shard data (binding the
kernel oracle tests to the system's data layout)."""
import numpy as np
import jax.numpy as jnp

from repro.core import build_shards
from repro.core.local_solver import _sweep
from repro.graph import random_graph
from repro.kernels.relax import relax_pallas, build_dst_tiled_layout


def test_kernel_sweep_equals_solver_sweep():
    g = random_graph(300, 1500, seed=21)
    sh = build_shards(g, 1)                       # single shard: all local
    loc_src = np.asarray(sh.loc_src[0])
    loc_dst = np.asarray(sh.loc_dst[0])
    loc_w = np.asarray(sh.loc_w[0])
    block = sh.block

    rng = np.random.default_rng(0)
    dist = rng.uniform(0, 30, block).astype(np.float32)
    dist[rng.random(block) < 0.4] = np.inf

    # jnp solver sweep with a full frontier
    frontier = jnp.ones((block,), bool)
    pruned = jnp.zeros((loc_w.shape[0],), bool)
    new_jnp, _, _ = _sweep(jnp.asarray(dist), frontier,
                           jnp.asarray(loc_src), jnp.asarray(loc_dst),
                           jnp.asarray(loc_w), pruned)

    # Pallas kernel sweep over the same edges
    valid = np.isfinite(loc_w)
    src_t, w_t, dr_t, bp = build_dst_tiled_layout(
        loc_src[valid], loc_dst[valid], loc_w[valid], block)
    dist_pad = jnp.asarray(np.concatenate(
        [dist, np.full(bp - block, np.inf, np.float32)]))
    new_k = relax_pallas(dist_pad, src_t, w_t, dr_t)

    np.testing.assert_allclose(np.asarray(new_jnp), np.asarray(new_k)[:block],
                               rtol=1e-6, atol=1e-6)
