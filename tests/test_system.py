"""End-to-end behaviour: the paper's full pipeline and the training stack."""
import numpy as np

from repro.core import SsspConfig, build_shards, solve_sim
from repro.graph import rmat_graph, road_grid_graph, dijkstra_reference


def test_paper_pipeline_end_to_end():
    """All phases together, as the paper runs them: graph processing ->
    partition -> pruning -> async SSSP -> termination (ToKa2 token ring),
    validated against Dijkstra."""
    g = rmat_graph(scale=8, edge_factor=8, seed=42)     # ParMat-like
    sh = build_shards(g, 8)
    cfg = SsspConfig(local_solver="delta", delta=6.0, toka="toka2",
                     prune_online=True)
    source = int(g.src[0])       # RMAT leaves some vertices isolated
    dist, stats = solve_sim(sh, source, cfg)
    ref = dijkstra_reference(g, source)
    np.testing.assert_allclose(dist, ref, rtol=1e-5, atol=1e-4)
    assert int(stats.rounds) > 0
    assert int(stats.relaxations) > 0


def test_road_network_pipeline():
    """Graph2-analog (road network): low cut fraction, long diameter."""
    g = road_grid_graph(side=24, seed=7)
    sh = build_shards(g, 6)
    dist, stats = solve_sim(sh, 0, SsspConfig())
    ref = dijkstra_reference(g, 0)
    np.testing.assert_allclose(dist, ref, rtol=1e-5, atol=1e-4)


def test_training_loss_decreases():
    """A few hundred steps of the smoke LM must learn the synthetic
    copy-structure (loss decreases materially)."""
    from repro.launch.train import main
    losses = main(["--arch", "deepseek-7b", "--smoke", "--steps", "60",
                   "--batch", "4", "--seq", "32", "--lr", "3e-3",
                   "--log-every", "1000"])
    assert losses[-1] < losses[0] - 0.3, (losses[0], losses[-1])


def test_mteps_accounting():
    """Stats support the paper's MTEPS metric (relaxations / time)."""
    g = rmat_graph(scale=7, edge_factor=8, seed=3)
    sh = build_shards(g, 4)
    _, stats = solve_sim(sh, 0, SsspConfig())
    assert int(stats.relaxations) >= g.n_edges * 0.1
