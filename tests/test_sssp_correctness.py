"""SP-Async vs Dijkstra oracle: property-based + config matrix."""
import numpy as np
import pytest
from _hyp import given, settings, strategies as st

from repro.core import SsspConfig, build_shards, solve_sim
from repro.graph import (random_graph, road_grid_graph, rmat_graph,
                         dijkstra_reference)


def _check(g, P, cfg, source=0):
    sh = build_shards(g, P)
    dist, stats = solve_sim(sh, source, cfg)
    ref = dijkstra_reference(g, source)
    np.testing.assert_allclose(dist, ref, rtol=1e-5, atol=1e-4)
    return stats


@settings(max_examples=15, deadline=None)
@given(n=st.integers(20, 120), m=st.integers(30, 400),
       p=st.integers(1, 6), seed=st.integers(0, 10_000))
def test_random_graphs_match_dijkstra(n, m, p, seed):
    g = random_graph(n=n, m=m, seed=seed)
    _check(g, p, SsspConfig())


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 1000), p=st.integers(2, 5))
def test_unreachable_vertices_stay_inf(seed, p):
    # no connectivity chain: some vertices must remain at +inf
    g = random_graph(n=80, m=60, seed=seed, ensure_connected_from=None)
    sh = build_shards(g, p)
    dist, _ = solve_sim(sh, 0, SsspConfig())
    ref = dijkstra_reference(g, 0)
    np.testing.assert_allclose(dist, ref, rtol=1e-5, atol=1e-4)
    assert np.isinf(ref).any() == np.isinf(dist).any()


@pytest.mark.parametrize("exchange", ["bucket", "pmin", "a2a_dense"])
def test_exchange_modes(exchange):
    g = random_graph(n=150, m=600, seed=3)
    _check(g, 5, SsspConfig(exchange=exchange))


@pytest.mark.parametrize("toka", ["toka0", "toka1", "toka2"])
def test_toka_modes(toka):
    g = road_grid_graph(side=12, seed=4)
    _check(g, 4, SsspConfig(toka=toka))


@pytest.mark.parametrize("solver", ["bellman", "delta", "pallas"])
def test_local_solvers(solver):
    g = rmat_graph(scale=7, edge_factor=6, seed=5)
    _check(g, 4, SsspConfig(local_solver=solver, delta=6.0))


def test_delta_reduces_relaxations():
    """Dijkstra-order settling (delta) must do less work than blind sweeps —
    the paper's motivation for intra-node Dijkstra."""
    g = road_grid_graph(side=14, seed=6)
    s_b = _check(g, 4, SsspConfig(local_solver="bellman", prune_online=False))
    s_d = _check(g, 4, SsspConfig(local_solver="delta", delta=6.0,
                                  prune_online=False))
    assert int(s_d.relaxations) < int(s_b.relaxations)


def test_nonzero_source():
    g = random_graph(n=100, m=400, seed=7)
    _check(g, 4, SsspConfig(), source=57)


def test_single_partition_equals_sequential():
    g = random_graph(n=120, m=500, seed=8)
    stats = _check(g, 1, SsspConfig())
    assert int(stats.msgs_sent) == 0      # no boundary -> no messages
