"""Training-loop features: gradient accumulation, schedules under jit."""
import numpy as np
import jax
import jax.numpy as jnp

from repro import compat
from repro.distributed.sharding import MeshAxes
from repro.models import transformer as tf
from repro.models.params import materialize
from repro.optim import AdamWConfig
from repro.optim.adamw import adamw_init

AX = MeshAxes(data=("data",), data_shards=1)
CFG = tf.TransformerConfig(name="t", n_layers=2, d_model=32, n_heads=4,
                           n_kv_heads=2, d_ff=64, vocab_size=64,
                           dtype="float32", attn_chunk=8)


def test_microbatched_step_matches_full_batch(mesh11):
    params = materialize(tf.param_defs(CFG, AX), jax.random.key(0), "float32")
    opt = adamw_init(params)
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, 64, (4, 16))),
             "labels": jnp.asarray(rng.integers(0, 64, (4, 16)))}
    with compat.set_mesh(mesh11):
        p1, _, m1 = jax.jit(tf.make_train_step(CFG, AX, AdamWConfig()))(
            params, opt, batch)
        p4, _, m4 = jax.jit(tf.make_train_step(CFG, AX, AdamWConfig(),
                                               microbatches=4))(
            params, opt, batch)
    assert abs(float(m1["loss"]) - float(m4["loss"])) < 1e-5
    for a, b in zip(jax.tree_util.tree_leaves(p1),
                    jax.tree_util.tree_leaves(p4)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_dtype_fence_is_identity_forward():
    x = jnp.asarray([1.0, 2.0], jnp.float32)
    y = tf.dtype_fence(x, jnp.bfloat16)
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    # backward casts the cotangent
    g = jax.grad(lambda t: jnp.sum(tf.dtype_fence(t, jnp.bfloat16) * 3.0))(x)
    assert g.dtype == jnp.bfloat16


def test_flash_bwd_matches_xla_attention_grads():
    rng = np.random.default_rng(0)
    B, S, H, Hkv, Dh = 2, 64, 4, 2, 16
    q = jnp.asarray(rng.standard_normal((B, S, H, Dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, Hkv, Dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, Hkv, Dh)), jnp.float32)
    sc = Dh ** -0.5
    def f1(q, k, v):
        return jnp.sum(jnp.sin(tf._attn_chunked(q, k, v, True, 0, sc, 16)))

    def f2(q, k, v):
        return jnp.sum(jnp.sin(tf._attn_xla(q, k, v, causal=True,
                                            q_offset=0, scale=sc)))
    g1 = jax.grad(f1, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f2, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-4)
