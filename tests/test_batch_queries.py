"""Batched multi-source query engine: parity, equivalence, acceptance.

Three layers, binding the query axis to the system:
  1. exchange-mode equivalence: bucket == pmin == a2a_dense distances for
     K=1 and K>1, in both sim and shmap backends
  2. batched-vs-sequential parity: solve_sim_batch(sources) == K
     independent solve_sim calls == dijkstra_reference per source, with
     per-query stats matching the isolated runs
  3. acceptance matrix (slow): K=8 sources on all three bench graphs for
     all three local solvers, sim and shmap
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import (SsspConfig, build_shards, solve_sim, solve_sim_batch)
from repro.graph import dijkstra_reference, random_graph, rmat_graph

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

EXCHANGES = ("bucket", "pmin", "a2a_dense")


def _sources(g, nq):
    rng = np.random.default_rng(17)
    return sorted(int(s) for s in
                  rng.choice(g.n_vertices, size=nq, replace=False))


# ------------------------------------------- exchange-mode equivalence ----

@pytest.mark.parametrize("nq", [1, 3])
def test_exchange_modes_equivalent_sim(nq):
    """bucket / pmin / a2a_dense move different bytes but must produce the
    same distances for every query in the batch."""
    g = random_graph(n=180, m=700, seed=21)
    sh = build_shards(g, 5)
    sources = _sources(g, nq)
    dists = {}
    for ex in EXCHANGES:
        d, _ = solve_sim_batch(sh, sources, SsspConfig(exchange=ex))
        dists[ex] = d
    refs = np.stack([dijkstra_reference(g, s) for s in sources])
    for ex in EXCHANGES:
        np.testing.assert_allclose(dists[ex], refs, rtol=1e-5, atol=1e-4)
        np.testing.assert_allclose(dists[ex], dists["bucket"],
                                   rtol=1e-6, atol=1e-6)


_SHMAP_PROG = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    os.environ["JAX_PLATFORMS"] = "cpu"
    import numpy as np
    from repro import compat
    from repro.core import SsspConfig, build_shards, solve_shmap_batch
    from repro.graph import random_graph, dijkstra_reference

    g = random_graph(n=180, m=700, seed=21)
    sh = build_shards(g, 4)
    mesh = compat.make_mesh((4,), ("d",))
    rng = np.random.default_rng(17)
    for nq in (1, 3):
        sources = sorted(int(s) for s in
                         rng.choice(g.n_vertices, size=nq, replace=False))
        refs = np.stack([dijkstra_reference(g, s) for s in sources])
        base = None
        for ex in ("bucket", "pmin", "a2a_dense"):
            d, _ = solve_shmap_batch(sh, sources, SsspConfig(exchange=ex),
                                     mesh, ("d",))
            assert np.allclose(d, refs, 1e-5, 1e-4), (ex, nq)
            base = d if base is None else base
            assert np.allclose(d, base, 1e-6, 1e-6), (ex, nq)
    print("SHMAP EXCHANGE OK")
""")


def test_exchange_modes_equivalent_shmap():
    """Same equivalence under shard_map with real collectives on a spoofed
    4-device mesh, K=1 and K=3 (subprocess: device count must be set
    before jax initializes)."""
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", _SHMAP_PROG], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "SHMAP EXCHANGE OK" in out.stdout


# --------------------------------------- batched-vs-sequential parity ----

def test_batch_matches_sequential_and_dijkstra():
    """solve_sim_batch(K sources) == K independent solve_sim calls ==
    dijkstra_reference, per source."""
    g = rmat_graph(scale=7, edge_factor=6, seed=13)
    sh = build_shards(g, 4)
    sources = _sources(g, 5)
    cfg = SsspConfig()
    batch_d, _ = solve_sim_batch(sh, sources, cfg)
    for k, s in enumerate(sources):
        single_d, _ = solve_sim(sh, s, cfg)
        np.testing.assert_allclose(batch_d[k], single_d, rtol=1e-6, atol=1e-6)
        np.testing.assert_allclose(batch_d[k], dijkstra_reference(g, s),
                                   rtol=1e-5, atol=1e-4)


def test_batch_per_query_stats_match_sequential():
    """With pruning off (pruning trajectories depend on batch composition),
    each query's rounds and relaxation count must be EXACTLY what its
    isolated run reports: the converged-query mask means stragglers never
    add work to finished queries."""
    g = random_graph(n=200, m=800, seed=23)
    sh = build_shards(g, 4)
    sources = _sources(g, 4)
    cfg = SsspConfig(prune_online=False)
    _, bstats = solve_sim_batch(sh, sources, cfg)
    q_rounds = np.asarray(bstats.q_rounds)
    q_relax = np.asarray(bstats.q_relaxations)
    for k, s in enumerate(sources):
        _, sstats = solve_sim(sh, s, cfg)
        assert int(q_rounds[k]) == int(sstats.rounds), (k, s)
        assert int(q_relax[k]) == int(sstats.relaxations), (k, s)
    # the batch runs as long as its slowest member, no longer
    assert int(bstats.rounds) == int(q_rounds.max())


def test_batch_stats_aggregate_consistency():
    """Scalar totals are the sums of the per-query columns; single-source
    wrappers report K=1 shapes."""
    g = random_graph(n=150, m=600, seed=29)
    sh = build_shards(g, 4)
    _, stats = solve_sim_batch(sh, _sources(g, 3),
                               SsspConfig(prune_online=False))
    assert stats.q_rounds.shape == (3,)
    assert int(stats.relaxations) == int(np.asarray(stats.q_relaxations).sum())
    _, s1 = solve_sim(sh, 0, SsspConfig())
    assert s1.q_rounds.shape == (1,)
    assert int(s1.q_rounds[0]) == int(s1.rounds)


@pytest.mark.parametrize("solver", ["bellman", "delta", "pallas"])
def test_batch_local_solvers(solver):
    """Every local solver backend handles the query axis."""
    g = rmat_graph(scale=6, edge_factor=5, seed=31)
    sh = build_shards(g, 3)
    sources = _sources(g, 4)
    d, _ = solve_sim_batch(sh, sources,
                           SsspConfig(local_solver=solver, delta=6.0))
    refs = np.stack([dijkstra_reference(g, s) for s in sources])
    np.testing.assert_allclose(d, refs, rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("toka", ["toka0", "toka1", "toka2"])
def test_batch_toka_modes(toka):
    """Per-query termination: each detector tracks K queries independently
    and the loop exits only when all are done."""
    g = random_graph(n=160, m=640, seed=37)
    sh = build_shards(g, 4)
    sources = _sources(g, 3)
    d, stats = solve_sim_batch(sh, sources, SsspConfig(toka=toka))
    refs = np.stack([dijkstra_reference(g, s) for s in sources])
    np.testing.assert_allclose(d, refs, rtol=1e-5, atol=1e-4)
    assert int(stats.rounds) >= int(np.asarray(stats.q_rounds).max())


def test_out_of_range_source_raises():
    """A bad source id must fail loudly, not return all-INF distances."""
    g = random_graph(n=100, m=300, seed=43)
    sh = build_shards(g, 4)
    with pytest.raises(ValueError, match="out of range"):
        solve_sim_batch(sh, [0, g.n_vertices + 5])
    with pytest.raises(ValueError, match="out of range"):
        solve_sim(sh, -1, SsspConfig())


def test_engine_cache_reused_by_wrappers():
    """Repeated wrapper solves against the same shards/config reuse ONE
    engine (and so one compiled round per K-bucket) — the amortization a
    query engine exists for."""
    from repro.core import engine_for
    g = random_graph(n=100, m=300, seed=47)
    sh = build_shards(g, 4)
    cfg = SsspConfig()
    assert engine_for(sh, cfg) is engine_for(sh, cfg)
    # distinct config -> distinct engine (its own compiled pipeline)
    assert engine_for(sh, cfg) is not engine_for(sh, SsspConfig(exchange="pmin"))
    eng = engine_for(sh, cfg)
    solve_sim_batch(sh, [0, 1, 2], cfg)
    traces = dict(eng.trace_counts)
    solve_sim_batch(sh, [5, 6, 7], cfg)   # same bucket, new sources
    assert eng.trace_counts == traces == {4: 1}


def test_sim_rounds_reported_from_carry():
    """Bugfix regression: solve_sim must report carry.rounds (the traced
    counter the shmap backend also reports), not the python loop index."""
    g = random_graph(n=120, m=500, seed=41)
    sh = build_shards(g, 4)
    _, stats = solve_sim(sh, 0, SsspConfig())
    # the jitted round increments carry.rounds exactly once per executed
    # round; q_rounds counts rounds while the (single) query was live, so
    # the two can only differ by the trailing all-done round
    assert 0 <= int(stats.rounds) - int(stats.q_rounds[0]) <= 1


# ------------------------------------------- acceptance matrix (slow) ----

_ACCEPT_PROG = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.environ["JAX_PLATFORMS"] = "cpu"
    import numpy as np
    from repro import compat
    from repro.core import (SsspConfig, build_shards, solve_shmap_batch,
                            solve_sim_batch)
    from repro.graph import rmat_graph, road_grid_graph, dijkstra_reference

    graphs = {
        "graph1-like": rmat_graph(scale=11, edge_factor=2, seed=1),
        "graph2-like": road_grid_graph(side=48, seed=2),
        "graph3-like": rmat_graph(scale=9, edge_factor=24, seed=3),
    }
    K = 8
    rng = np.random.default_rng(5)
    for name, g in graphs.items():
        sources = sorted(int(s) for s in
                         rng.choice(g.n_vertices, size=K, replace=False))
        refs = np.stack([dijkstra_reference(g, s) for s in sources])
        sh = build_shards(g, 8, enumerate_triangles=False)
        mesh = compat.make_mesh((8,), ("d",))
        for solver in ("bellman", "delta", "pallas"):
            cfg = SsspConfig(local_solver=solver, prune_online=False)
            d, _ = solve_sim_batch(sh, sources, cfg)
            assert np.allclose(d, refs, 1e-5, 1e-4), ("sim", name, solver)
            d, _ = solve_shmap_batch(sh, sources, cfg, mesh, ("d",))
            assert np.allclose(d, refs, 1e-5, 1e-4), ("shmap", name, solver)
        print(f"{name} OK")
    print("BATCH MATRIX OK")
""")


@pytest.mark.slow
def test_batch_acceptance_matrix():
    """Acceptance: K=8 sources match per-source dijkstra_reference on all
    three bench graphs for all three local solvers, sim and shmap."""
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", _ACCEPT_PROG], env=env,
                         capture_output=True, text=True, timeout=3000)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "BATCH MATRIX OK" in out.stdout
