import os

# Smoke tests and benches must see 1 device (the dry-run sets its own 512
# placeholder devices in a separate process). Keep CPU determinism.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402, F401  (initialize jax after JAX_PLATFORMS is set)
import pytest  # noqa: E402


@pytest.fixture(scope="session")
def mesh11():
    from repro import compat
    return compat.make_mesh((1, 1), ("data", "model"))


@pytest.fixture(scope="session")
def ax11():
    from repro.distributed.sharding import MeshAxes
    return MeshAxes(data=("data",), data_shards=1)
