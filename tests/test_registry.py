"""Every (arch × shape) cell must build: abstract structs + shardings.
(Compilation at production size is the dry-run's job — launch/dryrun.py.)"""
import jax
import pytest

from repro.configs.registry import (ARCHS, build_cell, list_cells)
from repro.distributed.sharding import MeshAxes


@pytest.fixture(scope="module")
def mesh():
    from repro import compat
    return compat.make_mesh((1, 1), ("data", "model"))


AX = MeshAxes(data=("data",), data_shards=1)


def test_40_assigned_cells_plus_sssp():
    cells = list_cells()
    assigned = [c for c in cells if c[0] != "sp-async"]
    assert len(assigned) == 40
    assert len(cells) == 44


@pytest.mark.parametrize("arch,shape", list_cells())
def test_cell_builds(arch, shape, mesh):
    cell = build_cell(arch, shape, mesh, AX)
    if cell.skip:
        assert "full-attention" in cell.skip
        return
    assert cell.step_fn is not None
    assert cell.args_struct is not None
    assert cell.model_flops > 0
    flat_a = jax.tree_util.tree_leaves(cell.args_struct)
    flat_s = jax.tree_util.tree_leaves(
        cell.in_shardings,
        is_leaf=lambda x: isinstance(x, jax.sharding.NamedSharding))
    assert len(flat_a) == len(flat_s), (len(flat_a), len(flat_s))


def test_long_500k_skips_are_documented():
    n_skipped = 0
    for arch, (family, _) in ARCHS.items():
        if family != "lm":
            continue
        cell = build_cell(arch, "long_500k", None, AX)
        assert cell.skip
        n_skipped += 1
    assert n_skipped == 5
