"""Ragged CSR-chunked layouts + streaming shard build (million-edge scale).

The acceptance bar for the ragged layout family is BIT-IDENTITY: same
stable dst-sort, same per-tile EB split, same Gauss-Seidel visitation order
as dense — the only difference is that padding chunks (inert, w=+inf) are
absent from the flat chunk grid. So every test here compares exact arrays,
never allclose.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (SsspConfig, build_shards, build_shards_stream,
                        solve_sim_batch)
from repro.graph import (SCALE_PRESETS, edge_chunks_of, get_generator,
                         preset_edge_stream, preset_graph, rmat_edge_stream,
                         rmat_graph)
from repro.graph.structure import csr_from_coo

TILE = dict(relax_vb=32, relax_eb=64, send_sb=32, send_eb=64,
            merge_vb=32, merge_eb=64)


@pytest.fixture(scope="module")
def graph():
    return rmat_graph(scale=7, edge_factor=8, seed=3)


@pytest.fixture(scope="module")
def shard_pair(graph):
    dense = build_shards(graph, 4, **TILE)
    ragged = build_shards(graph, 4, layout="ragged", **TILE)
    return dense, ragged


@pytest.mark.parametrize("round_", ["staged", "fused"])
@pytest.mark.parametrize("exchange", ["bucket", "async"])
@pytest.mark.parametrize("k", [1, 3])
def test_ragged_bit_identity_matrix(shard_pair, round_, exchange, k):
    """Ragged distances == dense distances, bit for bit, across the round
    x exchange x batch-size matrix on an all-Pallas pipeline."""
    dense, ragged = shard_pair
    cfg = SsspConfig(round=round_, exchange=exchange, local_solver="pallas",
                     send_backend="pallas", merge_backend="pallas",
                     pallas_sweeps=4)
    srcs = [0, 17, 90][:k]
    dd, sd = solve_sim_batch(dense, srcs, cfg)
    dr, sr = solve_sim_batch(ragged, srcs, cfg)
    assert jnp.array_equal(dd, dr)
    assert int(sd.rounds) == int(sr.rounds)


def test_ragged_skewed_power_law_smaller():
    """On a skewed degree distribution with a small chunk size, the dense
    layout pays max-tile chunks on EVERY tile; ragged pays per-tile actual.
    The gap is the whole point of the CSR-chunked grid."""
    rng = np.random.default_rng(7)
    n = 512
    # power-law-ish dst concentration: most edges land in a few tiles
    dst = (n * rng.power(8, 4000)).astype(np.int64) % n
    src = rng.integers(0, n, 4000)
    keep = src != dst
    w = rng.uniform(1, 20, keep.sum()).astype(np.float32)
    g = csr_from_coo(src[keep], dst[keep], w, n)
    dense = build_shards(g, 4, relax_vb=32, relax_eb=32, send_sb=32,
                        send_eb=32, merge_vb=32, merge_eb=32)
    ragged = build_shards(g, 4, layout="ragged", relax_vb=32, relax_eb=32,
                          send_sb=32, send_eb=32, merge_vb=32, merge_eb=32)
    lb_r, lb_d = ragged.layout_bytes(), dense.layout_bytes()
    assert lb_r["total_bytes"] < lb_d["total_bytes"]
    assert lb_r["bytes_per_edge"] < lb_d["bytes_per_edge"]
    # and it still solves identically
    cfg = SsspConfig(local_solver="pallas", send_backend="pallas",
                     merge_backend="pallas", pallas_sweeps=4)
    dd, _ = solve_sim_batch(dense, [0], cfg)
    dr, _ = solve_sim_batch(ragged, [0], cfg)
    assert jnp.array_equal(dd, dr)


def test_stream_build_equals_batch(graph):
    """build_shards_stream over edge chunks == build_shards on the
    materialized graph, field for field (the dedup + ordering mirror)."""
    ragged = build_shards(graph, 4, layout="ragged", **TILE)
    stream = build_shards_stream(edge_chunks_of(graph, chunk_edges=999),
                                 graph.n_vertices, 4, **TILE)
    for f in ("loc_src", "loc_dst", "loc_w", "cut_src", "cut_w", "cut_seg",
              "slot_owner", "slot_dstl", "slot_pos", "recv_idx",
              "rx_src", "rx_w", "rx_dstrel", "rx_eid", "rx_ctile",
              "tx_src", "tx_w", "tx_segrel", "tx_eid", "tx_ctile",
              "tx_payload_slot", "mx_pos", "mx_dstrel", "mx_valid",
              "mx_ctile"):
        a, b = getattr(stream, f), getattr(ragged, f)
        assert a.shape == b.shape and bool(jnp.array_equal(a, b)), f


def test_stream_build_chunking_invariant(graph):
    """The chunk size the consumer picks must not leak into the shards."""
    a = build_shards_stream(edge_chunks_of(graph, chunk_edges=100),
                            graph.n_vertices, 4, **TILE)
    b = build_shards_stream(edge_chunks_of(graph, chunk_edges=10_000),
                            graph.n_vertices, 4, **TILE)
    assert jnp.array_equal(a.rx_src, b.rx_src)
    assert jnp.array_equal(a.rx_w, b.rx_w)
    assert jnp.array_equal(a.tx_ctile, b.tx_ctile)
    assert jnp.array_equal(a.recv_idx, b.recv_idx)


def test_endpoint_validation():
    src = np.array([0, 1, 9])
    dst = np.array([1, -2, 3])
    w = np.ones(3, np.float32)
    with pytest.raises(ValueError, match=r"out-of-range edge endpoints: "
                                         r"1 src, 1 dst"):
        build_shards_stream(iter([(src, dst, w)]), 8, 2)
    g = rmat_graph(scale=5, edge_factor=4, seed=1)
    bad = g._replace(dst=jnp.where(jnp.arange(g.dst.shape[0]) == 0,
                                   g.n_vertices + 3, g.dst)) \
        if hasattr(g, "_replace") else None
    if bad is not None:
        with pytest.raises(ValueError, match="out-of-range"):
            build_shards(bad, 2)


def test_layout_bytes_shape():
    g = rmat_graph(scale=6, edge_factor=4, seed=2)
    for layout in ("dense", "ragged"):
        sh = build_shards(g, 2, layout=layout, **TILE)
        lb = sh.layout_bytes()
        assert lb["layout"] == layout
        assert set(lb["groups"]) == {"relax", "send", "merge"}
        assert lb["total_bytes"] > 0
        assert lb["bytes_per_edge"] >= lb["ideal_bytes_per_edge"] * 0.99
        for grp in lb["groups"].values():
            assert grp["bytes"] >= grp["ideal_bytes"] * 0.99
        if layout == "dense":
            for grp in lb["groups"].values():
                assert grp["bytes"] == grp["dense_bytes"]


def test_generator_registry_and_presets():
    assert get_generator("rmat") is rmat_graph
    with pytest.raises(KeyError, match="unknown generator"):
        get_generator("nope")
    assert set(SCALE_PRESETS) >= {"scale-1e5", "scale-1e6", "scale-1e7"}
    g = preset_graph("scale-1e5")
    assert 5e4 <= g.n_edges <= 5e5


def test_rmat_stream_chunk_invariant():
    """Same (seed, chunk_edges) -> same edge multiset regardless of how the
    consumer batches; and the stream feeds build_shards_stream end to end."""
    def collect(ce):
        cs = list(rmat_edge_stream(scale=6, edge_factor=4, seed=9,
                                   chunk_edges=ce))
        return (np.concatenate([c[0] for c in cs]),
                np.concatenate([c[1] for c in cs]),
                np.concatenate([c[2] for c in cs]))
    s1, d1, w1 = collect(64)
    s2, d2, w2 = collect(64)
    assert np.array_equal(s1, s2) and np.array_equal(w1, w2)
    n, chunks = preset_edge_stream("scale-1e5", chunk_edges=1 << 14)
    sh = build_shards_stream(chunks, n, 4)
    assert sh.layout == "ragged"
    assert sh.layout_bytes()["n_edges"] > 5e4


def test_ragged_rejects_unknown_layout():
    g = rmat_graph(scale=5, edge_factor=4, seed=1)
    with pytest.raises(ValueError, match="unknown layout"):
        build_shards(g, 2, layout="csr")
