"""Per-architecture smoke: reduced config, one forward/train step on CPU,
output shapes + no NaNs. One test per assigned arch (10)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import compat
from repro.configs.registry import ARCHS, _load
from repro.models.params import materialize
from repro.optim import AdamWConfig
from repro.optim.adamw import adamw_init

rng = np.random.default_rng(0)


def _gnn_batch(arch, cfg):
    N, E = 64, 192
    b = dict(edge_src=jnp.asarray(rng.integers(0, N, E), jnp.int32),
             edge_dst=jnp.asarray(rng.integers(0, N, E), jnp.int32))
    if arch == "gat-cora":
        b["node_feat"] = jnp.asarray(rng.standard_normal((N, cfg.d_in)), jnp.float32)
        b["labels"] = jnp.asarray(rng.integers(0, cfg.n_classes, N), jnp.int32)
    elif arch == "egnn":
        b["node_feat"] = jnp.asarray(rng.standard_normal((N, cfg.d_in)), jnp.float32)
        b["coords"] = jnp.asarray(rng.standard_normal((N, 3)), jnp.float32)
        b["labels"] = jnp.asarray(rng.standard_normal(N), jnp.float32)
    elif arch == "mace":
        b["node_feat"] = jnp.asarray(rng.integers(0, 10, (N, 1)), jnp.float32)
        b["coords"] = jnp.asarray(rng.standard_normal((N, 3)) * 2, jnp.float32)
        b["graph_id"] = jnp.asarray(np.repeat(np.arange(8), N // 8), jnp.int32)
        b["graph_energy"] = jnp.asarray(rng.standard_normal(8), jnp.float32)
    else:  # graphcast
        b["node_feat"] = jnp.asarray(rng.standard_normal((N, cfg.n_vars)), jnp.float32)
        b["edge_feat"] = jnp.asarray(rng.standard_normal((E, cfg.d_edge_in)), jnp.float32)
        b["labels"] = jnp.asarray(rng.standard_normal((N, cfg.n_vars)), jnp.float32)
    return b


@pytest.mark.parametrize("arch", list(ARCHS))
def test_arch_smoke(arch, mesh11, ax11):
    family, cfg = _load(arch, smoke=True)
    with compat.set_mesh(mesh11):
        if family == "lm":
            from repro.models import transformer as tf
            defs = tf.param_defs(cfg, ax11)
            params = materialize(defs, jax.random.key(0), cfg.dtype)
            opt = adamw_init(params)
            B, S = 2, 32
            batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S))),
                     "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)))}
            step = jax.jit(tf.make_train_step(cfg, ax11, AdamWConfig()))
            _, _, m = step(params, opt, batch)
            assert np.isfinite(float(m["loss"]))
            # forward shape check
            logits, kvs, _ = jax.jit(
                lambda p, t: tf.forward(p, t, cfg, ax11))(
                params, batch["tokens"])
            assert logits.shape == (B, S, cfg.vocab_size)
            assert np.isfinite(np.asarray(logits)).all()
        elif family == "gnn":
            from repro.models import gnn
            loss = {"gat-cora": gnn.gat_loss, "egnn": gnn.egnn_loss,
                    "mace": gnn.mace_loss, "graphcast": gnn.graphcast_loss}[arch]
            defs = {"gat-cora": gnn.gat_param_defs, "egnn": gnn.egnn_param_defs,
                    "mace": gnn.mace_param_defs,
                    "graphcast": gnn.graphcast_param_defs}[arch](cfg, ax11)
            params = materialize(defs, jax.random.key(0))
            opt = adamw_init(params)
            batch = _gnn_batch(arch, cfg)
            step = jax.jit(gnn.make_gnn_train_step(loss, cfg, ax11,
                                                   AdamWConfig(lr=1e-3)))
            _, _, m = step(params, opt, batch)
            assert np.isfinite(float(m["loss"]))
        else:
            from repro.models import autoint as ai
            defs = ai.autoint_param_defs(cfg, ax11)
            params = materialize(defs, jax.random.key(0))
            opt = adamw_init(params)
            B = 8
            batch = {"sparse_idx": jnp.asarray(
                rng.integers(0, cfg.total_vocab, (B, cfg.n_sparse, cfg.multi_hot)),
                jnp.int32),
                "labels": jnp.asarray(rng.integers(0, 2, B), jnp.int32)}
            step = jax.jit(ai.make_autoint_train_step(cfg, ax11, AdamWConfig()))
            _, _, m = step(params, opt, batch)
            assert np.isfinite(float(m["loss"]))
            serve = jax.jit(ai.make_autoint_serve_step(cfg, ax11))
            s = serve(params, batch)
            assert s.shape == (B,) and np.isfinite(np.asarray(s)).all()


def test_lm_decode_matches_forward(mesh11, ax11):
    """Prefill + decode must reproduce the full-forward logits (KV cache
    correctness — the serving path's core invariant)."""
    from repro.models import transformer as tf
    _, cfg = _load("deepseek-7b", smoke=True)
    defs = tf.param_defs(cfg, ax11)
    params = materialize(defs, jax.random.key(1), cfg.dtype)
    B, S = 2, 24
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    with compat.set_mesh(mesh11):
        full_logits, _, _ = jax.jit(
            lambda p, t: tf.forward(p, t, cfg, ax11))(params, toks)
        # prefill first S-4 tokens, then decode the remaining 4 one by one
        pre = S - 4
        _, kvs = jax.jit(tf.make_prefill_step(cfg, ax11))(
            params, {"tokens": toks[:, :pre]})
        pad = S - pre
        caches = tuple(jnp.pad(t, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
                       for t in kvs)
        serve = jax.jit(tf.make_serve_step(cfg, ax11))
        for i in range(pre, S):
            logits, caches = serve(params, toks[:, i:i + 1], caches,
                                   jnp.int32(i))
            ref = full_logits[:, i]
            np.testing.assert_allclose(np.asarray(logits), np.asarray(ref),
                                       rtol=2e-3, atol=2e-3)


def test_mace_rotation_invariance(mesh11, ax11):
    from repro.models import gnn
    _, cfg = _load("mace", smoke=True)
    defs = gnn.mace_param_defs(cfg, ax11)
    params = materialize(defs, jax.random.key(2))
    N, E = 48, 128
    coords = rng.standard_normal((N, 3)).astype(np.float32) * 2
    th = 0.9
    R = np.array([[np.cos(th), -np.sin(th), 0],
                  [np.sin(th), np.cos(th), 0], [0, 0, 1]], np.float32)
    base = dict(edge_src=jnp.asarray(rng.integers(0, N, E), jnp.int32),
                edge_dst=jnp.asarray(rng.integers(0, N, E), jnp.int32),
                node_feat=jnp.asarray(rng.integers(0, 10, (N, 1)), jnp.float32))
    with compat.set_mesh(mesh11):
        h0 = gnn.mace_forward(params, dict(base, coords=jnp.asarray(coords)),
                              cfg, ax11)
        h1 = gnn.mace_forward(params, dict(base, coords=jnp.asarray(coords @ R.T)),
                              cfg, ax11)
    np.testing.assert_allclose(np.asarray(h0[0]), np.asarray(h1[0]),
                               rtol=1e-3, atol=1e-4)
