"""Trishla (Algorithm 1) invariants: pruning never changes distances."""
import numpy as np
from _hyp import given, settings, strategies as st

from repro.core import SsspConfig, build_shards, solve_sim
from repro.graph import random_graph, rmat_graph, dijkstra_reference


@settings(max_examples=12, deadline=None)
@given(n=st.integers(30, 100), m=st.integers(100, 500),
       p=st.integers(1, 5), seed=st.integers(0, 10_000))
def test_offline_prune_preserves_distances(n, m, p, seed):
    g = random_graph(n=n, m=m, seed=seed)
    sh = build_shards(g, p)
    ref = dijkstra_reference(g, 0)
    d_off, s_off = solve_sim(sh, 0, SsspConfig(prune_offline_passes=2,
                                               prune_online=False))
    np.testing.assert_allclose(d_off, ref, rtol=1e-5, atol=1e-4)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 1000))
def test_online_prune_preserves_distances(seed):
    g = rmat_graph(scale=7, edge_factor=6, seed=seed)
    sh = build_shards(g, 4)
    ref = dijkstra_reference(g, 0)
    d_on, s_on = solve_sim(sh, 0, SsspConfig(prune_online=True, tri_chunk=64))
    np.testing.assert_allclose(d_on, ref, rtol=1e-5, atol=1e-4)


def test_pruning_happens_on_dense_graphs():
    """Triangle-rich graphs must actually lose edges (TEPS reduction)."""
    g = rmat_graph(scale=7, edge_factor=8, seed=1)
    sh = build_shards(g, 4)
    _, stats = solve_sim(sh, 0, SsspConfig(prune_offline_passes=1,
                                           prune_online=False))
    assert int(stats.pruned_edges) > 0


def test_pruning_reduces_relaxations():
    g = rmat_graph(scale=7, edge_factor=8, seed=2)
    sh = build_shards(g, 4)
    _, s0 = solve_sim(sh, 0, SsspConfig(prune_online=False))
    _, s1 = solve_sim(sh, 0, SsspConfig(prune_offline_passes=1,
                                        prune_online=False))
    assert int(s1.relaxations) <= int(s0.relaxations)


def test_idle_overlap_only_prunes_when_idle():
    """Online pruning happens in the idle branch; a single-partition run is
    never idle before termination, so nothing is pruned online."""
    g = random_graph(n=100, m=400, seed=3)
    sh = build_shards(g, 1)
    _, stats = solve_sim(sh, 0, SsspConfig(prune_online=True))
    assert int(stats.pruned_edges) == 0
