"""Fault injection, anti-entropy healing, toka3 timeout, and status.

The robustness contract under test:
  1. under every FaultPlan regime (drop/delay/duplicate/reorder), every
     exchange mode converges BIT-IDENTICAL to the fault-free solve —
     drops need `resend_period` anti-entropy, the other three are
     absorbed by the monotone idempotent scatter-min merge alone
  2. toka3 (the paper's timeout heuristic) terminates within its
     computed bound and agrees with toka0/1/2 on distances, fault-free
     and under faults
  3. `QueryResult.status` distinguishes converged / max_rounds /
     degraded via the fixpoint certificate, and non-converged results
     never reach the result LRU or the landmark cache
  4. `build_shards` rejects NaN / non-finite / negative edge weights

CI runs this file once per injection regime (FAULT_MODE=drop|delay|
duplicate|reorder restricts the matrix) and once unrestricted in tier1.
"""
import dataclasses
import os
import subprocess
import sys
import textwrap

import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, strategies as st

from repro.core import (FaultPlan, SsspConfig, SsspEngine, build_shards,
                        solve_sim)
from repro.core.toka import toka3_timeout
from repro.graph import dijkstra_reference, random_graph

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

EXCHANGES = ("bucket", "pmin", "a2a_dense")

# a plan per regime; drops are the only lossy regime, so only they need
# the anti-entropy resend to reach the fault-free fixpoint
_PLANS = {
    "drop": lambda seed: FaultPlan(drop=0.3, seed=seed, resend_period=4),
    "delay": lambda seed: FaultPlan(delay=0.4, seed=seed),
    "duplicate": lambda seed: FaultPlan(duplicate=0.4, seed=seed),
    "reorder": lambda seed: FaultPlan(reorder=0.4, seed=seed),
}
_MODES = tuple(m for m in _PLANS
               if m == os.environ.get("FAULT_MODE", m))


@pytest.fixture(scope="module")
def graph_and_shards():
    g = random_graph(n=96, m=360, seed=7)
    return g, build_shards(g, 4, enumerate_triangles=False)


@pytest.fixture(scope="module")
def baselines(graph_and_shards):
    """Fault-free solve per exchange mode (all bit-identical anyway)."""
    _, sh = graph_and_shards
    out = {}
    for ex in EXCHANGES:
        eng = SsspEngine.build(sh, SsspConfig(exchange=ex,
                                              prune_online=False))
        out[ex] = eng.solve([0, 5, 9])
    return out


# ------------------------------------------------ FaultPlan validation ----

def test_fault_plan_validation():
    for bad in (dict(drop=-0.1), dict(delay=1.5),
                dict(drop=0.6, duplicate=0.6),   # sum > 1
                dict(max_delay=0), dict(resend_period=-1)):
        with pytest.raises(ValueError):
            FaultPlan(**bad)
    assert not FaultPlan().active
    assert FaultPlan(drop=0.1).active
    with pytest.raises(TypeError):
        SsspConfig(faults={"drop": 0.1})
    with pytest.raises(ValueError):
        SsspConfig(toka3_safety=0.0)
    # inactive plan is a no-op config-wise: no fault pipeline is built
    assert SsspConfig(faults=FaultPlan()).fault_plan is None


# ------------------------------------------- the fault matrix (CI grid) ----

@pytest.mark.parametrize("exchange", EXCHANGES)
@pytest.mark.parametrize("mode", _MODES)
def test_fault_matrix_bit_identity(graph_and_shards, baselines, mode,
                                   exchange):
    """3 seeds x regime x exchange: faulted distances must be BIT-identical
    to fault-free and certified converged. Only round counts may move."""
    _, sh = graph_and_shards
    base = baselines[exchange]
    for seed in (0, 1, 2):
        cfg = SsspConfig(exchange=exchange, prune_online=False,
                         faults=_PLANS[mode](seed))
        res = SsspEngine.build(sh, cfg).solve([0, 5, 9])
        assert np.array_equal(res.dist, base.dist), (mode, exchange, seed)
        assert res.status == "converged"
        assert res.q_converged.all()


@pytest.mark.parametrize("k", [1, 3])
def test_stale_and_duplicates_never_corrupt(graph_and_shards, baselines, k):
    """Combined delay+duplicate+reorder (no drops, no resend) still reaches
    the exact fixpoint for K in {1, 3}: the merge is monotone and
    idempotent, so late or repeated messages can only re-apply bounds."""
    _, sh = graph_and_shards
    plan = FaultPlan(delay=0.25, duplicate=0.2, reorder=0.15, seed=11)
    for ex in EXCHANGES:
        cfg = SsspConfig(exchange=ex, prune_online=False, faults=plan)
        res = SsspEngine.build(sh, cfg).solve([0, 5, 9][:k])
        assert np.array_equal(res.dist, baselines[ex].dist[:k])
        assert res.status == "converged"


def test_fault_counters_surface_in_stats(graph_and_shards):
    _, sh = graph_and_shards
    res = SsspEngine.build(sh, SsspConfig(
        prune_online=False,
        faults=FaultPlan(drop=0.3, seed=1, resend_period=4))).solve([0, 5])
    assert int(res.stats.resends) > 0
    dres = SsspEngine.build(sh, SsspConfig(
        prune_online=False,
        faults=FaultPlan(delay=0.5, seed=1))).solve([0, 5])
    assert int(dres.stats.stale_merges) > 0


# ------------------------------------------------------ toka3 timeout ----

def test_toka3_matches_other_detectors(graph_and_shards, baselines):
    """toka3 must agree with toka0/1/2 on distances (round counts differ:
    the timeout pays its bound in extra quiet rounds)."""
    _, sh = graph_and_shards
    base = baselines["bucket"]
    rounds = {}
    for toka in ("toka0", "toka1", "toka2", "toka3"):
        res = SsspEngine.build(sh, SsspConfig(
            toka=toka, prune_online=False)).solve([0, 5, 9])
        assert np.array_equal(res.dist, base.dist), toka
        assert res.status == "converged"
        rounds[toka] = int(res.stats.rounds)
    assert rounds["toka3"] >= rounds["toka0"]


def test_toka3_matches_under_faults(graph_and_shards, baselines):
    plan = FaultPlan(drop=0.2, delay=0.1, duplicate=0.1, seed=3,
                     resend_period=4)
    _, sh = graph_and_shards
    base = baselines["bucket"]
    for toka in ("toka0", "toka1", "toka2", "toka3"):
        res = SsspEngine.build(sh, SsspConfig(
            toka=toka, prune_online=False, faults=plan)).solve([0, 5, 9])
        assert np.array_equal(res.dist, base.dist), toka
        assert res.status == "converged", toka


def test_toka3_terminates_within_bound(graph_and_shards):
    """rounds(toka3) <= rounds(toka0) + computed timeout: the streak can
    only start after real quiescence, and then fires exactly at the bound."""
    _, sh = graph_and_shards
    r0 = int(SsspEngine.build(sh, SsspConfig(
        toka="toka0", prune_online=False)).solve([0, 5, 9]).stats.rounds)
    r3 = int(SsspEngine.build(sh, SsspConfig(
        toka="toka3", prune_online=False)).solve([0, 5, 9]).stats.rounds)
    ie_total = int(np.asarray(sh.inter_edges).sum())
    bound = toka3_timeout(ie_total, sh.n_parts, safety=2.0)
    assert r3 <= r0 + bound + 1


def test_toka3_safety_scales_the_bound():
    assert toka3_timeout(1000, 8, safety=4.0) >= toka3_timeout(1000, 8,
                                                               safety=2.0)
    assert toka3_timeout(1000, 8, fault_slack=7) == \
        toka3_timeout(1000, 8) + 7


# ------------------------------------------------ graceful degradation ----

def test_unhealed_drops_degrade_loudly(graph_and_shards, baselines):
    """Heavy drops with NO resend: the detectors see quiet and fire, but
    the certificate catches the un-relaxed edges -> status='degraded',
    q_converged all-False, distances strictly above the true fixpoint."""
    _, sh = graph_and_shards
    res = SsspEngine.build(sh, SsspConfig(
        prune_online=False,
        faults=FaultPlan(drop=0.6, seed=2))).solve([0, 5, 9])
    assert res.status == "degraded"
    assert not res.q_converged.any()
    base = baselines["bucket"]
    assert not np.array_equal(res.dist, base.dist)
    assert np.all(np.asarray(res.dist) >= np.asarray(base.dist) - 1e-6)


def test_max_rounds_status(graph_and_shards):
    _, sh = graph_and_shards
    res = SsspEngine.build(sh, SsspConfig(
        prune_online=False, max_rounds=2)).solve([0, 5, 9])
    assert res.status == "max_rounds"
    assert not res.q_converged.all()


def test_degraded_results_never_cached(graph_and_shards):
    _, sh = graph_and_shards
    eng = SsspEngine.build(sh, SsspConfig(
        prune_online=False, faults=FaultPlan(drop=0.6, seed=2)),
        result_cache=16)
    first = eng.solve([0, 5])
    assert first.status == "degraded"
    again = eng.solve([0, 5])
    assert again.cache_hits == 0          # degraded rows were not admitted
    assert int(again.stats.rounds) > 0    # it really re-solved


def test_degraded_landmarks_rejected(graph_and_shards):
    _, sh = graph_and_shards
    eng = SsspEngine.build(sh, SsspConfig(
        prune_online=False, faults=FaultPlan(drop=0.6, seed=2)))
    with pytest.raises(ValueError, match="did not converge"):
        eng.precompute_landmarks([0, 5])


def test_certify_false_falls_back_to_detector(graph_and_shards):
    _, sh = graph_and_shards
    res = SsspEngine.build(sh, SsspConfig(prune_online=False),
                           certify=False).solve([0, 5])
    assert res.status == "converged" and res.q_converged.all()


def test_certificate_traces_do_not_pollute_trace_counts(graph_and_shards):
    _, sh = graph_and_shards
    eng = SsspEngine.build(sh, SsspConfig(prune_online=False))
    eng.solve([0, 5])
    eng.solve([9, 3])
    assert eng.trace_counts == {2: 1}     # the engine contract, unchanged
    assert eng.cert_traces == 1           # certificate compiled separately


# ------------------------------------------------------ input hardening ----

def _with_weight(g, i, value):
    w = np.asarray(g.weight).copy()
    w[i] = value
    return dataclasses.replace(g, weight=jnp.asarray(w))


@pytest.mark.parametrize("value,label", [(np.nan, "NaN"), (-1.0, "negative"),
                                         (np.inf, "non-finite")])
def test_build_shards_rejects_bad_weights(value, label):
    g = random_graph(n=40, m=80, seed=0)
    with pytest.raises(ValueError, match=label):
        build_shards(_with_weight(g, 3, value), 4,
                     enumerate_triangles=False)


def test_build_shards_ignores_padding_weights():
    # padding edges legitimately carry +inf; only valid edges are checked
    from repro.graph.structure import csr_from_coo, graph_to_numpy
    g = random_graph(n=40, m=80, seed=0)
    src, dst, w = graph_to_numpy(g)
    padded = csr_from_coo(src, dst, w, g.n_vertices,
                          e_pad=g.n_edges + 13)
    assert padded.e_pad > padded.n_edges
    assert np.isinf(np.asarray(padded.weight)[-1])
    build_shards(padded, 4, enumerate_triangles=False)


# ------------------------------------------- merge properties (oracle) ----

@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_scatter_min_merge_properties(seed):
    """The merge primitive itself: idempotent, commutative,
    permutation-invariant — the algebra the whole fault tolerance story
    rests on."""
    rng = np.random.default_rng(seed)
    n, m = 32, 48
    d = jnp.asarray(rng.uniform(0, 50, n).astype(np.float32))
    idx = rng.integers(0, n, size=m)
    a = jnp.asarray(rng.uniform(0, 50, m).astype(np.float32))
    b = jnp.asarray(rng.uniform(0, 50, m).astype(np.float32))
    once = d.at[idx].min(a)
    assert np.array_equal(once, once.at[idx].min(a))          # idempotent
    p = rng.permutation(m)
    assert np.array_equal(once, d.at[idx[p]].min(a[p]))       # perm-inv
    ab = d.at[idx].min(a).at[idx].min(b)
    ba = d.at[idx].min(b).at[idx].min(a)
    assert np.array_equal(ab, ba)                             # commutative
    # stale re-delivery (an older, larger bound) never changes the result
    stale = jnp.asarray(np.asarray(a) + 5.0)
    assert np.array_equal(once, once.at[idx].min(stale))


@settings(max_examples=4, deadline=None)
@given(seed=st.integers(0, 100))
def test_faulted_solve_matches_dijkstra(seed):
    """End to end vs the Dijkstra oracle, not just vs the fault-free
    solver: random graph, combined plan, distances exact."""
    g = random_graph(n=64, m=220, seed=seed)
    sh = build_shards(g, 3, enumerate_triangles=False)
    plan = FaultPlan(drop=0.2, delay=0.2, duplicate=0.1, seed=seed,
                     resend_period=3)
    dist, _ = solve_sim(sh, 0, SsspConfig(prune_online=False, faults=plan))
    np.testing.assert_allclose(dist, dijkstra_reference(g, 0),
                               rtol=1e-5, atol=1e-4)


# --------------------------------------------------------- shmap parity ----

_SHMAP_FAULTS_PROG = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    os.environ["JAX_PLATFORMS"] = "cpu"
    import numpy as np
    from repro import compat
    from repro.core import (FaultPlan, SsspConfig, SsspEngine, build_shards)
    from repro.graph import random_graph

    g = random_graph(n=96, m=360, seed=7)
    sh = build_shards(g, 4, enumerate_triangles=False)
    base = SsspEngine.build(sh, SsspConfig(prune_online=False)).solve([0, 5])

    mesh = compat.make_mesh((4,), ("d",))
    cfg = SsspConfig(prune_online=False, toka="toka3",
                     faults=FaultPlan(drop=0.2, seed=1, resend_period=4))
    eng = SsspEngine.build(sh, cfg, backend="shmap", mesh=mesh,
                           axis_names=("d",))
    res = eng.solve([0, 5])
    assert res.status == "converged", res.status
    assert res.q_converged.all()
    assert np.array_equal(res.dist, base.dist)
    assert int(res.stats.resends) > 0

    # degraded detection works across devices too
    deng = SsspEngine.build(sh, SsspConfig(
        prune_online=False, faults=FaultPlan(drop=0.6, seed=2)),
        backend="shmap", mesh=mesh, axis_names=("d",))
    dres = deng.solve([0, 5])
    assert dres.status == "degraded", dres.status
    print("SHMAP FAULTS OK")
""")


def test_shmap_faulted_solve_matches_sim():
    """shmap under faults: bit-identical to the fault-free sim solve,
    certificate-backed status on the multi-device path (subprocess:
    device count must be set before jax initializes)."""
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", _SHMAP_FAULTS_PROG], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "SHMAP FAULTS OK" in out.stdout
