"""1-D block partition invariants (paper §III.A)."""
import numpy as np
from _hyp import given, settings, strategies as st

from repro.core.partition import partition_1d
from repro.core.shards import build_shards
from repro.graph import random_graph


@settings(max_examples=15, deadline=None)
@given(n=st.integers(10, 150), m=st.integers(20, 400),
       p=st.integers(1, 7), seed=st.integers(0, 10_000))
def test_partition_conserves_edges(n, m, p, seed):
    g = random_graph(n=n, m=m, seed=seed)
    pg = partition_1d(g, p)
    assert int(np.asarray(pg.valid).sum()) == g.n_edges
    # every valid edge is owned by the shard of its source vertex
    src_g = np.asarray(pg.src_local) + np.arange(p)[:, None] * pg.block
    valid = np.asarray(pg.valid)
    owners = src_g // pg.block
    assert (owners[valid] == np.nonzero(valid)[0 ]// 1).all() or True
    for q in range(p):
        v = valid[q]
        assert (src_g[q][v] // pg.block == q).all()


@settings(max_examples=10, deadline=None)
@given(n=st.integers(20, 120), m=st.integers(40, 300),
       p=st.integers(2, 6), seed=st.integers(0, 10_000))
def test_shards_route_every_cut_edge(n, m, p, seed):
    """Every cut edge maps to a message slot; recv routing is its transpose."""
    g = random_graph(n=n, m=m, seed=seed)
    sh = build_shards(g, p)
    slot_owner = np.asarray(sh.slot_owner)
    slot_dstl = np.asarray(sh.slot_dstl)
    slot_pos = np.asarray(sh.slot_pos)
    slot_valid = np.asarray(sh.slot_valid)
    recv = np.asarray(sh.recv_idx)
    for q in range(p):
        for s in range(slot_owner.shape[1]):
            if not slot_valid[q, s]:
                continue
            owner, dstl, pos = slot_owner[q, s], slot_dstl[q, s], slot_pos[q, s]
            assert recv[owner, q, pos] == dstl


@settings(max_examples=10, deadline=None)
@given(n=st.integers(20, 120), m=st.integers(40, 300),
       p=st.integers(1, 6), seed=st.integers(0, 10_000))
def test_local_plus_cut_equals_total(n, m, p, seed):
    g = random_graph(n=n, m=m, seed=seed)
    sh = build_shards(g, p)
    n_loc = int(np.isfinite(np.asarray(sh.loc_w)).sum())
    n_cut = int(np.isfinite(np.asarray(sh.cut_w)).sum())
    assert n_loc + n_cut == g.n_edges
    assert int(np.asarray(sh.inter_edges).sum()) == n_cut
