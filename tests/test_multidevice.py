"""shard_map production path on 8 fake host devices (subprocess — device
count must be set before jax initializes, so this cannot share the test
process)."""
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

PROG = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.environ["JAX_PLATFORMS"] = "cpu"
    import numpy as np
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.graph import random_graph, dijkstra_reference
    from repro.core import SsspConfig, build_shards, solve_shmap
    from repro.distributed.collectives import ring_permute, flat_rank

    from repro import compat
    mesh = compat.make_mesh((2, 4), ("data", "model"))
    axes = ("data", "model")

    # 1) ring_permute moves rank r's value to rank r+1 over the 2-axis ring
    def ring_prog():
        r = flat_rank(axes)
        return ring_permute(r, axes)
    out = jax.jit(compat.shard_map(lambda: ring_prog()[None], mesh=mesh,
                                   in_specs=(), out_specs=P(axes),
                                   check_vma=False))()
    got = np.asarray(out)
    want = np.roll(np.arange(8), 1)
    assert (got == want).all(), (got, want)
    print("RING OK")

    # 2) SSSP shard_map == oracle, all exchanges and detectors
    g = random_graph(220, 900, seed=11)
    sh = build_shards(g, 8)
    ref = dijkstra_reference(g, 0)
    for cfg in [SsspConfig(), SsspConfig(exchange="pmin"),
                SsspConfig(exchange="a2a_dense"),
                SsspConfig(toka="toka1"),
                SsspConfig(toka="toka2", local_solver="delta"),
                SsspConfig(local_solver="pallas")]:
        dist, stats = solve_shmap(sh, 0, cfg, mesh, axes)
        assert np.allclose(dist, ref, 1e-5, 1e-4), cfg
    print("SHMAP OK")

    # 3) LM train step under a real 2x4 mesh (GSPMD path)
    from repro.distributed.sharding import MeshAxes
    from repro.models import transformer as tf
    from repro.models.params import materialize
    from repro.optim import AdamWConfig
    from repro.optim.adamw import adamw_init
    ax = MeshAxes(data=("data",), data_shards=2)
    from repro.configs.registry import _load
    _, cfg = _load("qwen3-moe-235b-a22b", smoke=True)
    defs = tf.param_defs(cfg, ax)
    params = materialize(defs, jax.random.key(0), cfg.dtype)
    opt = adamw_init(params)
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 32))),
             "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 32)))}
    # place inputs on the mesh (sharding constraints resolve against it)
    rep = jax.NamedSharding(mesh, P())
    params, opt, batch = jax.device_put((params, opt, batch), rep)
    step = jax.jit(tf.make_train_step(cfg, ax, AdamWConfig()))
    with compat.set_mesh(mesh):
        _, _, m = step(params, opt, batch)
    assert np.isfinite(float(m["loss"]))
    print("LM MESH OK")
""")


@pytest.mark.slow
def test_multidevice_subprocess():
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", PROG], env=env,
                         capture_output=True, text=True, timeout=900)
    assert res.returncode == 0, res.stderr[-3000:]
    assert "RING OK" in res.stdout
    assert "SHMAP OK" in res.stdout
    assert "LM MESH OK" in res.stdout
