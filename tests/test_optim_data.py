"""Optimizer + schedules + data pipelines + sampler."""
import numpy as np
import jax
import jax.numpy as jnp
from _hyp import given, settings, strategies as st

from repro.optim import (AdamWConfig, adamw_init, adamw_update,
                         clip_by_global_norm, cosine_schedule,
                         linear_warmup_cosine)
from repro.data import RecsysBatcher, synthetic_lm_batch
from repro.graph import random_graph
from repro.graph.sampler import NeighborSampler


def test_adamw_minimizes_quadratic():
    params = {"x": jnp.asarray([3.0, -2.0])}
    opt = adamw_init(params)
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
    def loss(p):
        return jnp.sum(p["x"] ** 2)
    for _ in range(200):
        g = jax.grad(loss)(params)
        params, opt, _ = adamw_update(params, g, opt, cfg)
    assert float(loss(params)) < 1e-3


def test_grad_clip_caps_norm():
    tree = {"a": jnp.full((10,), 10.0)}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert float(norm) > 1.0
    assert abs(float(jnp.linalg.norm(clipped["a"])) - 1.0) < 1e-5


def test_schedules_bounded():
    for s in [0, 10, 100, 1000]:
        v = float(linear_warmup_cosine(jnp.int32(s), warmup=50,
                                       total_steps=1000))
        assert 0.0 <= v <= 1.0
    assert float(cosine_schedule(jnp.int32(0), 100)) == 1.0


def test_token_stream_learnable_structure():
    b = synthetic_lm_batch(np.random.default_rng(0), 4, 32, 100)
    assert b["tokens"].shape == (4, 32)
    # copy structure: many labels equal the current token (repeat positions
    # that were themselves overwritten dilute the raw 50% rate)
    eq = float(jnp.mean((b["tokens"] == b["labels"]).astype(jnp.float32)))
    assert eq > 0.2


def test_recsys_batcher_shapes():
    it = RecsysBatcher(batch=16, n_fields=5, vocab_per_field=100, multi_hot=2)
    b = next(it)
    assert b["sparse_idx"].shape == (16, 5, 2)
    assert int(jnp.max(b["sparse_idx"])) < 500


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 100), bn=st.integers(2, 12))
def test_neighbor_sampler_invariants(seed, bn):
    g = random_graph(200, 1200, seed=seed)
    s = NeighborSampler(g, fanouts=(5, 3), seed=seed)
    seeds = np.random.default_rng(seed).choice(200, bn, replace=False)
    nodes, src, dst, n_real = s.sample(seeds)
    assert len(nodes) == s.max_nodes(bn)
    assert n_real <= s.max_nodes(bn)
    # all real local ids within range; padding uses max_nodes sentinel
    real_edges = src < s.max_nodes(bn)
    assert (dst[real_edges] < n_real).all()
    assert (src[real_edges] < n_real).all()
    # seeds come first in the node list
    assert (nodes[:bn] == seeds).all()
