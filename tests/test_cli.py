"""launch/sssp_run CLI: argument parsing + end-to-end tiny-graph runs.

The runner had no direct tests; these pin down the flag surface (including
the new --landmarks/--warm-start/--result-cache) and the validated
end-to-end path on graphs small enough for seconds-scale runs.
"""
import sys

import pytest

from repro.launch import sssp_run


def _run(capsys, monkeypatch, *argv):
    monkeypatch.setattr(sys, "argv", ["sssp_run", *argv])
    sssp_run.main()
    return capsys.readouterr().out


TINY = ("--graph", "random", "--scale", "7", "--edge-factor", "4",
        "--parts", "4", "--no-prune")


# ----------------------------------------------------------- parsing ----

def test_bad_flag_values_rejected(monkeypatch, capsys):
    for argv in (["--graph", "mystery"],
                 ["--exchange", "carrier-pigeon"],
                 ["--solver", "dijkstra"],
                 ["--warm-start", "oracle"],
                 ["--backend", "mpi"]):
        monkeypatch.setattr(sys, "argv", ["sssp_run", *argv])
        with pytest.raises(SystemExit):
            sssp_run.main()


def test_warm_start_requires_landmarks(monkeypatch, capsys):
    monkeypatch.setattr(sys, "argv",
                        ["sssp_run", *TINY, "--warm-start", "landmark"])
    with pytest.raises(SystemExit):
        sssp_run.main()
    assert "--landmarks" in capsys.readouterr().err


def test_out_of_range_source_rejected(monkeypatch, capsys):
    monkeypatch.setattr(sys, "argv",
                        ["sssp_run", *TINY, "--sources", "999999"])
    with pytest.raises(ValueError, match="out of range"):
        sssp_run.main()


# ------------------------------------------------------- end to end ----

def test_single_source_run_validates(capsys, monkeypatch):
    out = _run(capsys, monkeypatch, *TINY, "--source", "3", "--validate")
    assert "validation vs Dijkstra (1 query): OK" in out
    assert "reachable:" in out


def test_batched_run_with_explicit_sources(capsys, monkeypatch):
    out = _run(capsys, monkeypatch, *TINY, "--sources", "0,5,9",
               "--exchange", "pmin", "--toka", "toka1", "--solver", "delta",
               "--validate")
    assert "sources=[0, 5, 9]" in out
    assert "query[2] source=9:" in out
    assert "validation vs Dijkstra (3 queries): OK" in out


def test_sampled_batch_run(capsys, monkeypatch):
    out = _run(capsys, monkeypatch, *TINY, "--num-sources", "4", "--batch")
    assert "bucket K=4" in out
    assert "query[3]" in out


def test_warm_start_run_with_landmarks_and_cache(capsys, monkeypatch):
    out = _run(capsys, monkeypatch, *TINY, "--sources", "0,5",
               "--warm-start", "landmark", "--landmarks", "3",
               "--result-cache", "8", "--validate")
    assert "landmarks: 3 pivots solved" in out
    assert "warm_start=landmark" in out
    assert "[warm-started]" in out
    assert "cache_hits=2/2" in out and "rounds=0" in out
    assert "validation vs Dijkstra (2 queries): OK" in out


def test_result_cache_without_warm_start(capsys, monkeypatch):
    out = _run(capsys, monkeypatch, *TINY, "--sources", "1,8",
               "--result-cache", "4")
    assert "cache_hits=2/2" in out


# -------------------------------------------------------------- async ----

def test_async_lag_flag_validation(monkeypatch, capsys):
    for argv in ([*TINY, "--async-lag", "0", "--exchange", "async"],
                 [*TINY, "--async-lag", "2"],  # sync exchange ignores lag
                 [*TINY, "--async-lag", "2", "--exchange", "async_ppermute"]):
        monkeypatch.setattr(sys, "argv", ["sssp_run", *argv])
        with pytest.raises(SystemExit):
            sssp_run.main()
        assert "--async-lag" in capsys.readouterr().err


def test_async_run_reports_overlap_and_validates(capsys, monkeypatch):
    out = _run(capsys, monkeypatch, *TINY, "--sources", "0,5,9",
               "--exchange", "async", "--validate")
    assert "async: overlap=" in out
    assert "stale_merges=" in out and "bytes_moved=" in out
    assert "validation vs Dijkstra (3 queries): OK" in out


def test_async_ppermute_lagged_run_validates(capsys, monkeypatch):
    out = _run(capsys, monkeypatch, *TINY, "--source", "3",
               "--exchange", "async_ppermute", "--round", "fused",
               "--validate")
    assert "async: overlap=" in out
    assert "validation vs Dijkstra (1 query): OK" in out


# ------------------------------------------------------------- faults ----

def test_faulted_run_heals_and_validates(capsys, monkeypatch):
    out = _run(capsys, monkeypatch, *TINY, "--sources", "0,5",
               "--fault-drop", "0.2", "--resend-period", "4",
               "--toka", "toka3", "--validate")
    assert "status: converged (converged 2/2 queries)" in out
    assert "resends=" in out
    assert "validation vs Dijkstra (2 queries): OK" in out


def test_validate_fails_loudly_on_degraded(capsys, monkeypatch):
    # heavy drops, no resend: --validate must exit 1 BEFORE the Dijkstra
    # check, naming the unconverged sources
    monkeypatch.setattr(sys, "argv",
                        ["sssp_run", *TINY, "--sources", "0,5",
                         "--fault-drop", "0.6", "--fault-seed", "2",
                         "--validate"])
    with pytest.raises(SystemExit, match="1"):
        sssp_run.main()
    out = capsys.readouterr().out
    assert "validation FAILED: status=degraded" in out
