"""ToKa termination-detection behaviour."""
import numpy as np

from repro.core import SsspConfig, build_shards, solve_sim
from repro.core.partition import inter_edge_counts, partition_1d
from repro.graph import random_graph, dijkstra_reference


def _solve(g, p, cfg):
    sh = build_shards(g, p)
    dist, stats = solve_sim(sh, 0, cfg)
    return dist, stats


def test_toka2_costs_token_circulation_rounds():
    """The token ring needs O(P) extra rounds after quiescence (white
    circuit + red circuit) — the paper's asynchrony tax, measurable."""
    g = random_graph(n=120, m=500, seed=1)
    _, s0 = _solve(g, 6, SsspConfig(toka="toka0"))
    _, s2 = _solve(g, 6, SsspConfig(toka="toka2"))
    assert int(s2.rounds) > int(s0.rounds)
    assert int(s2.rounds) >= int(s0.rounds) + 6  # >= one extra circuit


def test_toka2_correct_at_all_partition_counts():
    g = random_graph(n=90, m=350, seed=2)
    ref = dijkstra_reference(g, 0)
    for p in (1, 2, 3, 5, 8):
        dist, _ = _solve(g, p, SsspConfig(toka="toka2"))
        np.testing.assert_allclose(dist, ref, rtol=1e-5, atol=1e-4)


def test_toka1_budget_formula():
    """Algorithm 4: bound = n_parts * inter_edges per shard."""
    g = random_graph(n=80, m=300, seed=3)
    pg = partition_1d(g, 4)
    bounds = inter_edge_counts(pg)
    assert bounds.shape == (4,)
    assert bounds.sum() > 0


def test_toka1_terminates_and_is_correct_here():
    """toka1 is a heuristic; on these graphs the budget is loose enough
    that it only fires after quiescence — distances must be exact."""
    g = random_graph(n=100, m=400, seed=4)
    ref = dijkstra_reference(g, 0)
    dist, stats = _solve(g, 4, SsspConfig(toka="toka1"))
    np.testing.assert_allclose(dist, ref, rtol=1e-5, atol=1e-4)


def test_all_detectors_agree_on_distances():
    g = random_graph(n=110, m=450, seed=5)
    d0, _ = _solve(g, 5, SsspConfig(toka="toka0"))
    d1, _ = _solve(g, 5, SsspConfig(toka="toka1"))
    d2, _ = _solve(g, 5, SsspConfig(toka="toka2"))
    np.testing.assert_allclose(d0, d1, rtol=1e-6)
    np.testing.assert_allclose(d0, d2, rtol=1e-6)
