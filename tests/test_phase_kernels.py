"""Phase-pipeline round engine: send/merge kernels + backend registry.

Four layers, binding the pipeline to the system:
  1. kernel-vs-ref property tests (via tests/_hyp.py): the slot-tiled send
     pack and the msg-tiled merge scatter match their pure-jnp oracles on
     random graphs for K in {1, 3}
  2. e2e equivalence: every (send_backend x merge_backend) combination
     produces BIT-identical distances and per-query stats to the XLA
     baseline across all exchange modes, in sim and (subprocess) shmap
  3. config validation: unknown backend names raise eagerly at
     SsspConfig construction, not inside tracing
  4. layout fallback: pallas backends degrade to xla with a ONE-TIME
     warning when build_shards skipped the layouts
"""
import os
import subprocess
import sys
import textwrap
import warnings

import numpy as np
import jax.numpy as jnp
import pytest

from _hyp import given, settings, strategies as st
from repro.core import (SsspConfig, build_shards, phases, sim_phase_fns,
                        solve_sim_batch)
from repro.graph import dijkstra_reference, random_graph
from repro.kernels.merge import (build_msg_tiled_layout, merge_scatter_pallas,
                                 merge_scatter_ref)
from repro.kernels.send import (build_slot_tiled_layout, send_pack_pallas,
                                send_payload_bucket, send_pack_ref)

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

EXCHANGES = ("bucket", "pmin", "a2a_dense")
BACKENDS = ("xla", "pallas")


def _sources(g, nq, seed=17):
    rng = np.random.default_rng(seed)
    return sorted(int(s) for s in
                  rng.choice(g.n_vertices, size=nq, replace=False))


# ------------------------------------------------ kernel property tests ----

def _random_send_state(n_vertices, e_cut, n_slots, nq, seed):
    """Random cut-edge pack inputs honoring the shard contract: seg ids
    sorted, last_sent only ever holds values a previous pack produced (so
    INF or a real candidate)."""
    rng = np.random.default_rng(seed)
    seg = np.sort(rng.integers(0, n_slots, size=e_cut))
    src = rng.integers(0, n_vertices, size=e_cut)
    w = rng.uniform(1, 20, size=e_cut).astype(np.float32)
    dist = rng.uniform(0, 50, size=(nq, n_vertices)).astype(np.float32)
    dist[rng.random((nq, n_vertices)) < 0.3] = np.inf
    last = rng.uniform(0, 60, size=(nq, n_slots)).astype(np.float32)
    last[rng.random((nq, n_slots)) < 0.5] = np.inf
    valid = np.zeros(n_slots, bool)
    valid[np.unique(seg)] = True
    pruned = rng.random(e_cut) < 0.2
    return src, seg, w, dist, last, valid, pruned


@settings(max_examples=6, deadline=None)
@given(n=st.integers(40, 300), e=st.integers(10, 600),
       s=st.integers(4, 200), nq=st.integers(1, 3), seed=st.integers(0, 999))
def test_send_kernel_matches_ref(n, e, s, nq, seed):
    src, seg, w, dist, last, valid, pruned = _random_send_state(
        n, e, s, nq, seed)
    w_masked = np.where(pruned, np.inf, w)
    ref = send_pack_ref(jnp.asarray(dist), jnp.asarray(src, jnp.int32),
                        jnp.asarray(w_masked), jnp.asarray(seg, jnp.int32),
                        s, jnp.asarray(valid), jnp.asarray(last))
    src_t, w_t, seg_t, eid_t, _sp = build_slot_tiled_layout(
        src, seg, w, s, sb=128, eb=256)
    pruned_t = jnp.take(jnp.asarray(pruned, jnp.int32), eid_t, mode="fill",
                        fill_value=0)
    out = send_pack_pallas(jnp.asarray(dist), jnp.asarray(last),
                           jnp.asarray(valid), src_t, w_t, seg_t, pruned_t,
                           sb=128, eb=256)
    for got, want in zip(out[:2], ref[:2]):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    np.testing.assert_array_equal(np.asarray(out[2]), np.asarray(ref[2]))


@settings(max_examples=6, deadline=None)
@given(block=st.integers(16, 300), p=st.integers(1, 8), c=st.integers(1, 40),
       nq=st.integers(1, 3), seed=st.integers(0, 999))
def test_merge_kernel_matches_ref(block, p, c, nq, seed):
    """Random routing table + contract-consistent incoming values (a
    position without a route never carries a finite value — in the solver
    no sender owns a slot for it)."""
    rng = np.random.default_rng(seed)
    ridx = rng.integers(0, block + 1, size=(p, c))     # block = sentinel
    incoming = rng.uniform(0, 50, size=(nq, p * c)).astype(np.float32)
    incoming[rng.random((nq, p * c)) < 0.4] = np.inf
    incoming[:, (ridx == block).reshape(-1)] = np.inf
    dist = rng.uniform(0, 40, size=(nq, block)).astype(np.float32)
    dist[rng.random((nq, block)) < 0.3] = np.inf

    ref = merge_scatter_ref(jnp.asarray(dist), jnp.asarray(incoming),
                            jnp.asarray(ridx.reshape(-1), jnp.int32))
    pos_t, dr_t, v_t, _bp = build_msg_tiled_layout(ridx, block, vb=128,
                                                   eb=256)
    out = merge_scatter_pallas(jnp.asarray(dist), jnp.asarray(incoming),
                               pos_t, dr_t, v_t, vb=128, eb=256)
    np.testing.assert_array_equal(np.asarray(out[0]), np.asarray(ref[0]))
    np.testing.assert_array_equal(np.asarray(out[1]), np.asarray(ref[1]))
    np.testing.assert_array_equal(np.asarray(out[2]), np.asarray(ref[2]))


def test_payload_gather_matches_scatter():
    """The static payload inverse (tx_payload_slot) reproduces the XLA
    scatter exactly: each bucket position receives at most one slot."""
    g = random_graph(n=200, m=900, seed=3)
    sh = build_shards(g, 6)
    rng = np.random.default_rng(4)
    S, C, P = sh.n_slots, sh.bucket_cap, sh.n_parts
    for p in range(P):
        val = rng.uniform(0, 30, size=(2, S)).astype(np.float32)
        val[rng.random((2, S)) < 0.5] = np.inf
        val[:, ~np.asarray(sh.slot_valid[p])] = np.inf
        ref = np.stack([
            np.full((P, C), np.inf, np.float32) for _ in range(2)])
        owner = np.asarray(sh.slot_owner[p])
        pos = np.asarray(sh.slot_pos[p])
        for k in range(2):
            np.minimum.at(ref[k], (owner, pos), val[k])
        got = send_payload_bucket(jnp.asarray(val), sh.tx_payload_slot[p])
        np.testing.assert_array_equal(np.asarray(got), ref)


# ------------------------------------------------ e2e backend matrix ----

@pytest.mark.parametrize("nq", [1, 3])
def test_backend_matrix_bit_identical_sim(nq):
    """Every (send_backend x merge_backend) combination is BIT-identical
    to the XLA baseline — distances AND per-query q_rounds/q_relaxations —
    for every exchange mode (the kernels change the math's address order,
    never its values: min is exact)."""
    g = random_graph(n=180, m=700, seed=21)
    sh = build_shards(g, 5)
    sources = _sources(g, nq)
    refs = np.stack([dijkstra_reference(g, s) for s in sources])
    for ex in EXCHANGES:
        base = None
        for sb in BACKENDS:
            for mb in BACKENDS:
                cfg = SsspConfig(exchange=ex, send_backend=sb,
                                 merge_backend=mb, toka="toka2")
                d, stats = solve_sim_batch(sh, sources, cfg)
                np.testing.assert_allclose(d, refs, rtol=1e-5, atol=1e-4)
                key = (np.asarray(d), np.asarray(stats.q_rounds),
                       np.asarray(stats.q_relaxations),
                       int(stats.msgs_sent), int(stats.msgs_recv))
                if base is None:
                    base = key
                    continue
                np.testing.assert_array_equal(key[0], base[0], err_msg=str((ex, sb, mb)))
                np.testing.assert_array_equal(key[1], base[1], err_msg=str((ex, sb, mb)))
                np.testing.assert_array_equal(key[2], base[2], err_msg=str((ex, sb, mb)))
                assert key[3:] == base[3:], (ex, sb, mb)


_SHMAP_PROG = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    os.environ["JAX_PLATFORMS"] = "cpu"
    import numpy as np
    from repro import compat
    from repro.core import SsspConfig, build_shards, solve_shmap_batch
    from repro.graph import random_graph, dijkstra_reference

    g = random_graph(n=180, m=700, seed=21)
    sh = build_shards(g, 4)
    mesh = compat.make_mesh((4,), ("d",))
    rng = np.random.default_rng(17)
    sources = sorted(int(s) for s in
                     rng.choice(g.n_vertices, size=3, replace=False))
    refs = np.stack([dijkstra_reference(g, s) for s in sources])
    for ex in ("bucket", "pmin", "a2a_dense"):
        base = None
        for sb in ("xla", "pallas"):
            for mb in ("xla", "pallas"):
                cfg = SsspConfig(exchange=ex, send_backend=sb,
                                 merge_backend=mb)
                d, stats = solve_shmap_batch(sh, sources, cfg, mesh, ("d",))
                assert np.allclose(d, refs, 1e-5, 1e-4), (ex, sb, mb)
                key = (np.asarray(d), np.asarray(stats.q_rounds),
                       np.asarray(stats.q_relaxations))
                if base is None:
                    base = key
                    continue
                assert (key[0] == base[0]).all(), (ex, sb, mb)
                assert (key[1] == base[1]).all(), (ex, sb, mb)
                assert (key[2] == base[2]).all(), (ex, sb, mb)
    print("SHMAP BACKEND MATRIX OK")
""")


def test_backend_matrix_shmap():
    """Same bit-identity under shard_map with real collectives on a
    spoofed 4-device mesh (subprocess: device count must be set before jax
    initializes)."""
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", _SHMAP_PROG], env=env,
                         capture_output=True, text=True, timeout=1800)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "SHMAP BACKEND MATRIX OK" in out.stdout


def test_phase_fns_compose_to_round():
    """The per-phase benchmark hook drives the same stages the round
    dispatches: one manual local->send->exchange->merge pass starting from
    converged distances is a fixpoint (no new frontier, nothing sent)."""
    g = random_graph(n=120, m=500, seed=33)
    sh = build_shards(g, 4)
    cfg = SsspConfig(send_backend="pallas", merge_backend="pallas",
                     prune_online=False)
    d, _ = solve_sim_batch(sh, [0, 7], cfg)
    fns = sim_phase_fns(sh, cfg)
    nq, blk, P = 2, sh.block, sh.n_parts
    dist = jnp.asarray(
        np.moveaxis(np.pad(np.asarray(d), ((0, 0), (0, P * blk - g.n_vertices)),
                           constant_values=np.inf).reshape(nq, P, blk), 1, 0))
    active = jnp.zeros((P, nq, blk), bool)
    pruned = jnp.zeros((P, sh.e_loc + sh.e_cut), bool)
    cursor = jnp.zeros((P,), jnp.int32)
    last = jnp.full((P, nq, sh.n_slots), np.inf, jnp.float32)
    dist2, _, _, _, _ = fns["local"](dist, active, pruned, cursor)
    payload, _, sends = fns["send"](dist2, pruned, last)
    incoming = fns["exchange"](payload)
    dist3, new_active, _ = fns["merge"](dist2, incoming)
    np.testing.assert_array_equal(np.asarray(dist3), np.asarray(dist))
    assert not bool(np.asarray(new_active).any())
    # last_sent starts at INF here, so the converged distances DO transmit
    # once — but a second pass against the updated last_sent must be quiet
    _, last2, _ = fns["send"](dist2, pruned, last)
    _, _, sends2 = fns["send"](dist2, pruned, last2)
    assert not np.asarray(sends2).any()


# ------------------------------------------------ config validation ----

@pytest.mark.parametrize("field,bad", [
    ("exchange", "ring"),
    ("toka", "toka9"),
    ("local_solver", "dijkstra"),
    ("send_backend", "cuda"),
    ("merge_backend", "triton"),
    ("round", "megakernel"),
])
def test_config_rejects_unknown_backends(field, bad):
    """Eager validation: the ValueError arrives at construction and names
    the valid options."""
    with pytest.raises(ValueError, match="valid:"):
        SsspConfig(**{field: bad})


def test_registry_lists_backends():
    assert set(phases.backends("send")) == {"xla", "pallas"}
    assert set(phases.backends("merge")) == {"xla", "pallas"}
    assert set(phases.backends("exchange")) == {"bucket", "pmin", "a2a_dense",
                                                "async", "async_bucket",
                                                "async_ppermute"}
    assert set(phases.backends("local_solver")) == {"bellman", "delta",
                                                    "pallas"}
    assert set(phases.backends("round")) == {"staged", "fused"}
    with pytest.raises(ValueError, match="valid:"):
        phases.resolve("send", "nope")


# ------------------------------------------------ layout fallbacks ----

def test_pallas_backends_fall_back_with_one_time_warning():
    g = random_graph(150, 600, seed=9)
    sh = build_shards(g, 4, relax_layout=False, comm_layout=False)
    assert not (sh.has_send_layout or sh.has_merge_layout)
    cfg = SsspConfig(local_solver="pallas", send_backend="pallas",
                     merge_backend="pallas")
    phases._WARNED.clear()
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        d, _ = solve_sim_batch(sh, [0], cfg)
    msgs = sorted(str(w.message) for w in rec)
    assert len(msgs) == 3
    assert any("send_backend='pallas' falling back" in m for m in msgs)
    assert any("merge_backend='pallas' falling back" in m for m in msgs)
    assert any("local_solver='pallas' falling back" in m for m in msgs)
    np.testing.assert_allclose(d[0], dijkstra_reference(g, 0),
                               rtol=1e-5, atol=1e-4)
    # one-time: a second solve stays silent
    with warnings.catch_warnings(record=True) as rec2:
        warnings.simplefilter("always")
        solve_sim_batch(sh, [1], cfg)
    assert not rec2


def test_comm_layout_shapes():
    """build_shards carries the stacked slot/msg-tiled layouts with the
    kernel contract's shapes; every real cut edge appears exactly once."""
    g = random_graph(200, 800, seed=10)
    sh = build_shards(g, 4)
    P = sh.n_parts
    assert sh.tx_src.shape[0] == P
    assert sh.tx_src.shape == sh.tx_w.shape == sh.tx_segrel.shape == sh.tx_eid.shape
    assert sh.tx_src.shape[1] * sh.tx_sb >= sh.n_slots
    assert sh.tx_payload_slot.shape == (P, P, sh.bucket_cap)
    assert sh.mx_pos.shape == sh.mx_dstrel.shape == sh.mx_valid.shape
    assert sh.mx_pos.shape[1] * sh.mx_vb >= sh.block
    for p in range(P):
        eids = np.asarray(sh.tx_eid[p]).ravel()
        real = np.sort(eids[eids < sh.e_cut])
        valid = np.isfinite(np.asarray(sh.cut_w[p]))
        np.testing.assert_array_equal(real, np.nonzero(valid)[0])
        # merge layout covers exactly the routed positions
        routed = np.asarray(sh.recv_idx[p]).reshape(-1) < sh.block
        pos = np.asarray(sh.mx_pos[p]).ravel()
        v = np.asarray(sh.mx_valid[p]).ravel() > 0
        np.testing.assert_array_equal(np.sort(pos[v]), np.nonzero(routed)[0])


# ------------------------------------------- acceptance matrix (slow) ----

_ACCEPT_PROG = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.environ["JAX_PLATFORMS"] = "cpu"
    import numpy as np
    from repro import compat
    from repro.core import (SsspConfig, build_shards, solve_shmap_batch,
                            solve_sim_batch)
    from repro.graph import rmat_graph, road_grid_graph, dijkstra_reference

    graphs = {
        "graph1-like": rmat_graph(scale=11, edge_factor=2, seed=1),
        "graph2-like": road_grid_graph(side=48, seed=2),
        "graph3-like": rmat_graph(scale=9, edge_factor=24, seed=3),
    }
    K = 8
    rng = np.random.default_rng(5)
    for name, g in graphs.items():
        sources = sorted(int(s) for s in
                         rng.choice(g.n_vertices, size=K, replace=False))
        refs = np.stack([dijkstra_reference(g, s) for s in sources])
        sh = build_shards(g, 8, enumerate_triangles=False)
        mesh = compat.make_mesh((8,), ("d",))
        for label, cfg in [
            ("staged", SsspConfig(local_solver="pallas",
                                  send_backend="pallas",
                                  merge_backend="pallas",
                                  prune_online=False)),
            ("fused", SsspConfig(round="fused", prune_online=False)),
        ]:
            d, _ = solve_sim_batch(sh, sources, cfg)
            assert np.allclose(d, refs, 1e-5, 1e-4), ("sim", label, name)
            d, _ = solve_shmap_batch(sh, sources, cfg, mesh, ("d",))
            assert np.allclose(d, refs, 1e-5, 1e-4), ("shmap", label, name)
        print(f"{name} OK")
    print("FULL PALLAS PIPELINE OK")
""")


@pytest.mark.slow
def test_full_pallas_pipeline_acceptance():
    """Acceptance: the all-pallas round (relax + send + merge kernels)
    matches Dijkstra for K=8 on all three bench graphs, sim and shmap."""
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", _ACCEPT_PROG], env=env,
                         capture_output=True, text=True, timeout=3000)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "FULL PALLAS PIPELINE OK" in out.stdout
