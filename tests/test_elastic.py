"""Elastic restart: a checkpoint written under one device topology restores
onto a different one (the lose-a-pod / resize scenario). The save side runs
in THIS process (1 device); the restore side runs in a subprocess with 8
spoofed devices and explicit NamedShardings."""
import os
import subprocess
import sys
import textwrap

import jax.numpy as jnp
import pytest

from repro.checkpoint import save_checkpoint

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

RESTORE_PROG = textwrap.dedent("""
    import os, sys
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.environ["JAX_PLATFORMS"] = "cpu"
    import numpy as np
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.checkpoint import restore_checkpoint

    ckpt = sys.argv[1]
    from repro import compat
    mesh = compat.make_mesh((2, 4), ("data", "model"))
    target = {"w": jax.ShapeDtypeStruct((16, 32), jnp.float32),
              "b": jax.ShapeDtypeStruct((32,), jnp.float32)}
    sh = {"w": NamedSharding(mesh, P("data", "model")),
          "b": NamedSharding(mesh, P("model"))}
    tree = restore_checkpoint(ckpt, 7, target, shardings=sh)
    assert tree["w"].sharding == sh["w"], tree["w"].sharding
    assert np.allclose(np.asarray(tree["w"]),
                       np.arange(16 * 32, dtype=np.float32).reshape(16, 32))
    assert len(tree["w"].devices()) == 8
    print("ELASTIC OK")
""")


@pytest.mark.slow
def test_restore_onto_larger_mesh(tmp_path):
    tree = {"w": jnp.arange(16 * 32, dtype=jnp.float32).reshape(16, 32),
            "b": jnp.ones((32,), jnp.float32)}
    save_checkpoint(str(tmp_path), 7, tree)
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", RESTORE_PROG, str(tmp_path)],
                         env=env, capture_output=True, text=True, timeout=300)
    assert res.returncode == 0, res.stderr[-2000:]
    assert "ELASTIC OK" in res.stdout
