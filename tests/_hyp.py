"""``hypothesis`` compatibility shim for the property-based tests.

When hypothesis is installed (see requirements-dev.txt) the real library is
re-exported unchanged. When it is absent — e.g. a bare container — the
tests still COLLECT and RUN: ``given`` degrades to a deterministic sampler
that draws a fixed number of pseudo-random examples per test (seeded, so
failures reproduce), and ``settings`` becomes a no-op. Only the
``st.integers`` strategy is emulated because that is all these tests use.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies  # noqa: F401
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:

    import numpy as np

    HAVE_HYPOTHESIS = False
    _FALLBACK_EXAMPLES = 5

    class _Integers:
        def __init__(self, lo, hi):
            self.lo, self.hi = lo, hi

        def draw(self, rng):
            return int(rng.integers(self.lo, self.hi + 1))

    class strategies:  # noqa: N801 — mimics the hypothesis module name
        @staticmethod
        def integers(min_value, max_value):
            return _Integers(min_value, max_value)

    def settings(**_kw):
        def deco(f):
            return f
        return deco

    def given(**strats):
        keys = sorted(strats)

        def deco(f):
            def wrapper():
                rng = np.random.default_rng(0xC0FFEE)
                for _ in range(_FALLBACK_EXAMPLES):
                    f(**{k: strats[k].draw(rng) for k in keys})

            # NOT functools.wraps: copying __wrapped__ would expose the
            # original signature and pytest would treat params as fixtures
            wrapper.__name__ = f.__name__
            wrapper.__doc__ = f.__doc__
            return wrapper

        return deco
