"""Warm-start subsystem: landmark cache, warm_init seeding, result LRU.

The contract under test (the acceptance bar of the warm-start PR):
  1. landmark seeds are true upper bounds, and warm-started solves are
     BIT-identical to cold solves — distances and correctness — for
     K in {1, 3}, across sim + shmap and all three exchange modes
  2. a repeated source converges in strictly fewer rounds when seeded
     from the landmark cache (its seed IS the solved fixpoint)
  3. result-cache hits perform ZERO rounds and return the stored rows
     bit-for-bit; cached sources are stripped from a batch BEFORE bucket
     padding; the LRU evicts in recency order
  4. graph-epoch invalidation orphans both caches
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import (CachedRow, LandmarkCache, ResultCache, SsspConfig,
                        SsspEngine, build_shards, phases,
                        shard_distance_rows)
from repro.graph import dijkstra_reference, random_graph, road_grid_graph

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

EXCHANGES = ("bucket", "pmin", "a2a_dense")
LANDMARKS = [0, 60, 120]


@pytest.fixture(scope="module")
def graph_and_shards():
    g = random_graph(n=180, m=700, seed=21)
    return g, build_shards(g, 5)


def _warm_pair(sh, exchange="bucket", result_cache=0):
    cold = SsspEngine.build(sh, SsspConfig(prune_online=False,
                                           exchange=exchange))
    warm = SsspEngine.build(sh, SsspConfig(prune_online=False,
                                           exchange=exchange,
                                           warm_start="landmark"),
                            result_cache=result_cache)
    warm.precompute_landmarks(LANDMARKS)
    return cold, warm


# ------------------------------------------------ config / registry ----

def test_warm_start_validated_eagerly():
    assert phases.backends("warm_init") == ("landmark", "none")
    with pytest.raises(ValueError, match="warm_init"):
        SsspConfig(warm_start="bogus")
    assert SsspConfig().warm_start == "none"


def test_shard_distance_rows_layout():
    rows = np.arange(6, dtype=np.float32).reshape(2, 3)   # L=2, n=3
    land = np.asarray(shard_distance_rows(rows, n_parts=2, block=2))
    assert land.shape == (2, 2, 2)                         # [P, L, block]
    assert land[0, 0].tolist() == [0.0, 1.0]
    assert land[1, 0, 0] == 2.0 and np.isinf(land[1, 0, 1])  # pad vertex
    assert land[1, 1, 0] == 5.0


def test_landmark_cache_metadata(graph_and_shards):
    _, sh = graph_and_shards
    _, warm = _warm_pair(sh)
    lm = warm.landmarks
    assert isinstance(lm, LandmarkCache)
    assert lm.sources == tuple(LANDMARKS) and lm.epoch == 0
    assert lm.n_landmarks == len(LANDMARKS)
    # the documented cost model: 4 B x L x block per shard
    assert lm.nbytes_per_shard == 4 * len(LANDMARKS) * sh.block
    assert lm.dist.shape == (sh.n_parts, len(LANDMARKS), sh.block)
    with pytest.raises(ValueError, match="at least one landmark"):
        warm.precompute_landmarks([])


# ------------------------------------------------- seed correctness ----

def test_seed_is_upper_bound(graph_and_shards):
    """The triangle-inequality seed must dominate the true distances —
    this is what makes warm-started fixpoints exact."""
    g, sh = graph_and_shards
    _, warm = _warm_pair(sh)
    from repro.core.warmstart import landmark_seed_stacked
    sources = np.asarray([3, 99], np.int32)
    seed = np.asarray(landmark_seed_stacked(
        warm.landmarks.dist, sources, np.ones(2, bool)))
    seed = np.moveaxis(seed, 0, 1).reshape(2, -1)[:, : g.n_vertices]
    for k, s in enumerate([3, 99]):
        ref = dijkstra_reference(g, s)
        finite = np.isfinite(seed[k])
        assert np.all(seed[k][finite] >= ref[finite] - 1e-6)
        # a finite seed may only appear where the vertex is reachable
        assert np.all(np.isfinite(ref[finite]))


@pytest.mark.parametrize("exchange", EXCHANGES)
@pytest.mark.parametrize("nq", [1, 3])
def test_warm_bit_identical_to_cold_sim(graph_and_shards, exchange, nq):
    g, sh = graph_and_shards
    cold, warm = _warm_pair(sh, exchange)
    rng = np.random.default_rng(5)
    sources = sorted(int(s) for s in
                     rng.choice(g.n_vertices, size=nq, replace=False))
    rc, rw = cold.solve(sources), warm.solve(sources)
    assert rw.warm_started and not rc.warm_started
    assert np.array_equal(rc.dist, rw.dist)
    refs = np.stack([dijkstra_reference(g, s) for s in sources])
    np.testing.assert_allclose(rw.dist, refs, rtol=1e-5, atol=1e-4)


def test_repeated_source_converges_in_fewer_rounds():
    """A repeated source's seed IS its solved fixpoint: the warm solve
    confirms quiescence in ~1 round instead of re-propagating the wave
    (the road grid has the deep round structure that makes this visible).
    """
    g = road_grid_graph(side=24, seed=2)
    sh = build_shards(g, 8, enumerate_triangles=False)
    cold = SsspEngine.build(sh, SsspConfig(prune_online=False))
    warm = SsspEngine.build(sh, SsspConfig(prune_online=False,
                                           warm_start="landmark"))
    warm.precompute_landmarks([0, 287])
    rc, rw = cold.solve([287]), warm.solve([287])
    assert np.array_equal(rc.dist, rw.dist)
    assert int(rw.q_rounds[0]) < int(rc.q_rounds[0])
    assert int(rw.q_rounds[0]) <= 2


def test_warm_without_landmarks_stays_cold(graph_and_shards):
    """warm_start='landmark' with no precomputed cache must not fail —
    solves run cold until the cache exists."""
    g, sh = graph_and_shards
    eng = SsspEngine.build(sh, SsspConfig(warm_start="landmark"))
    res = eng.solve([3])
    assert not res.warm_started
    np.testing.assert_allclose(res.dist[0], dijkstra_reference(g, 3),
                               rtol=1e-5, atol=1e-4)


# ----------------------------------------------------- result cache ----

def test_result_cache_lru_semantics():
    lru = ResultCache(2)
    row = CachedRow(np.zeros(3, np.float32))
    assert lru.get(1, 0) is None and lru.misses == 1
    lru.put(1, 0, row)
    lru.put(2, 0, row)
    assert lru.get(1, 0) is row and lru.hits == 1
    lru.put(3, 0, row)               # evicts 2 (LRU), keeps refreshed 1
    assert lru.get(2, 0) is None
    assert lru.get(1, 0) is row and lru.get(3, 0) is row
    assert len(lru) == 2
    # epoch is part of the key: a bumped epoch misses
    assert lru.get(1, 1) is None
    # size 0 disables storage entirely
    off = ResultCache(0)
    off.put(1, 0, row)
    assert off.get(1, 0) is None and len(off) == 0


def test_exact_repeat_zero_rounds(graph_and_shards):
    g, sh = graph_and_shards
    eng = SsspEngine.build(sh, SsspConfig(prune_online=False),
                           result_cache=8)
    first = eng.solve([3, 17])
    assert first.cache_hits == 0
    hit = eng.solve([3, 17])
    assert hit.cache_hits == 2 and hit.bucket_k == 0
    assert int(hit.stats.rounds) == 0
    assert np.array_equal(hit.q_rounds, [0, 0])
    assert np.array_equal(hit.dist, first.dist)
    assert not hit.compiled and hit.compile_s == 0.0


def test_cached_sources_stripped_before_padding(graph_and_shards):
    """A partially-cached batch rides the bucket of its UNCACHED remainder
    — the strip happens before power-of-two padding."""
    g, sh = graph_and_shards
    eng = SsspEngine.build(sh, SsspConfig(prune_online=False),
                           result_cache=8)
    eng.solve([3, 17, 99])                      # populate (bucket 4)
    mixed = eng.solve([3, 40, 17, 99, 41])      # 3 cached + 2 new
    assert mixed.cache_hits == 3
    assert mixed.bucket_k == 2                  # bucket of the remainder
    refs = np.stack([dijkstra_reference(g, s) for s in [3, 40, 17, 99, 41]])
    np.testing.assert_allclose(mixed.dist, refs, rtol=1e-5, atol=1e-4)
    # cached rows did zero rounds THIS call; new rows did real rounds
    assert mixed.q_rounds[0] == 0 and mixed.q_rounds[2] == 0
    assert mixed.q_rounds[1] > 0 and mixed.q_rounds[4] > 0


def test_duplicate_sources_coalesce_with_cache(graph_and_shards):
    g, sh = graph_and_shards
    eng = SsspEngine.build(sh, SsspConfig(prune_online=False),
                           result_cache=8)
    res = eng.solve([5, 5, 5])                  # dedupe -> one K=1 solve
    assert res.bucket_k == 1
    assert np.array_equal(res.dist[0], res.dist[1])
    ref = dijkstra_reference(g, 5)
    np.testing.assert_allclose(res.dist[2], ref, rtol=1e-5, atol=1e-4)


def test_cache_off_is_bitcompatible_default(graph_and_shards):
    """result_cache=0 (the default) must be the exact pre-cache behavior:
    repeats re-solve, nothing is stored."""
    g, sh = graph_and_shards
    eng = SsspEngine.build(sh, SsspConfig(prune_online=False))
    a, b = eng.solve([3]), eng.solve([3])
    assert b.cache_hits == 0 and int(b.stats.rounds) > 0
    assert np.array_equal(a.dist, b.dist)
    assert len(eng.result_cache) == 0


def test_drain_rides_result_cache(graph_and_shards):
    """submit/drain inherits the strip: already-cached submissions drain
    without solving (zero rounds), per-handle slicing stays correct."""
    g, sh = graph_and_shards
    eng = SsspEngine.build(sh, SsspConfig(prune_online=False),
                           result_cache=8, max_bucket=4)
    eng.solve([3, 17])
    h1, h2 = eng.submit(3), eng.submit([17, 40])
    eng.drain()
    r1, r2 = h1.result(), h2.result()
    assert int(r1.q_rounds[0]) == 0              # fully cached row
    assert int(r2.q_rounds[0]) == 0 and int(r2.q_rounds[1]) > 0
    np.testing.assert_allclose(r2.dist[1], dijkstra_reference(g, 40),
                               rtol=1e-5, atol=1e-4)


def test_warmup_bypasses_result_cache(graph_and_shards):
    """warmup(k) must compile the FULL bucket even though its repeated
    probe sources would dedupe to K=1 through the cache layer."""
    _, sh = graph_and_shards
    eng = SsspEngine.build(sh, SsspConfig(prune_online=False),
                           result_cache=8)
    assert eng.warmup(4) > 0
    assert eng.trace_counts == {4: 1}
    assert not eng.solve([7, 8, 9]).compiled


def test_warmup_covers_sim_seed_program(graph_and_shards):
    """On a warm sim engine the seed program is separate from the round:
    a cold trace of the bucket (from precompute) must not let warmup()
    report 0.0 while the seed still compiles at first serve."""
    _, sh = graph_and_shards
    eng = SsspEngine.build(sh, SsspConfig(prune_online=False,
                                          warm_start="landmark"))
    eng.precompute_landmarks([0, 60])        # cold path traces bucket 2
    assert eng.warmup(2) > 0                 # warm seed still cold
    res = eng.solve([7, 8])
    assert res.warm_started and not res.compiled
    assert eng.warmup(2) == 0.0


def test_precompute_rejects_asymmetric_distances():
    """The triangle-inequality seed needs d(src,l) but only has d(l,src);
    a directed graph whose pivot cross-distances expose the asymmetry must
    be rejected instead of silently under-seeding solves."""
    g = random_graph(n=120, m=600, seed=3, undirected=False)
    eng = SsspEngine.build(build_shards(g, 4, enumerate_triangles=False),
                           SsspConfig(warm_start="landmark"))
    with pytest.raises(ValueError, match="symmetric"):
        eng.precompute_landmarks([0, 5, 9])


# ------------------------------------------------------ invalidation ----

def test_epoch_invalidation_orphans_both_caches(graph_and_shards):
    g, sh = graph_and_shards
    _, warm = _warm_pair(sh, result_cache=8)
    warm.solve([3])
    hit = warm.solve([3])
    assert hit.cache_hits == 1
    assert warm.invalidate_caches() == 1
    assert warm.landmarks is None and len(warm.result_cache) == 0
    miss = warm.solve([3])
    assert miss.cache_hits == 0 and not miss.warm_started
    np.testing.assert_allclose(miss.dist[0], dijkstra_reference(g, 3),
                               rtol=1e-5, atol=1e-4)
    # re-precompute restores warm serving under the new epoch
    warm.precompute_landmarks(LANDMARKS)
    assert warm.landmarks.epoch == 1
    assert warm.solve([9]).warm_started


# ----------------------------------------------------- shmap parity ----

_SHMAP_WARM_PROG = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    os.environ["JAX_PLATFORMS"] = "cpu"
    import numpy as np
    from repro import compat
    from repro.core import SsspConfig, SsspEngine, build_shards
    from repro.graph import random_graph

    g = random_graph(n=180, m=700, seed=21)
    sh = build_shards(g, 4)
    mesh = compat.make_mesh((4,), ("d",))
    for ex in ("bucket", "pmin", "a2a_dense"):
        cold = SsspEngine.build(sh, SsspConfig(exchange=ex), backend="shmap",
                                mesh=mesh, axis_names=("d",))
        warm = SsspEngine.build(sh, SsspConfig(exchange=ex,
                                               warm_start="landmark"),
                                backend="shmap", mesh=mesh,
                                axis_names=("d",), result_cache=8)
        warm.precompute_landmarks([0, 60, 120])
        for srcs in ([3], [17, 99, 150]):
            rc, rw = cold.solve(srcs), warm.solve(srcs)
            assert rw.warm_started, (ex, srcs)
            assert np.array_equal(rc.dist, rw.dist), (ex, srcs)
        rc = cold.solve([60])
        rw = warm._solve_batch((60,))      # bypass LRU: seed-path rounds
        assert np.array_equal(rc.dist, rw.dist), ex
        assert int(rw.q_rounds[0]) < int(rc.q_rounds[0]), ex
        hit = warm.solve([60])
        assert hit.cache_hits == 1 and int(hit.stats.rounds) == 0, ex
    # warmup must compile the WARM whole-solve program: the cold trace of
    # the same bucket (from precompute_landmarks) does not cover it
    weng = SsspEngine.build(sh, SsspConfig(warm_start="landmark"),
                            backend="shmap", mesh=mesh, axis_names=("d",))
    weng.precompute_landmarks([0, 60, 120])    # cold program, bucket 4
    assert weng.warmup(3) > 0                  # warm program still cold
    r = weng.solve([5, 6, 7])
    assert r.warm_started and not r.compiled
    assert weng.warmup(3) == 0.0
    print("SHMAP WARM OK")
""")


def test_warm_bit_identical_shmap():
    """shmap: landmark-seeded solves bit-match cold across all exchange
    modes; repeated pivots converge in fewer rounds; LRU hits skip the
    solve (subprocess: device count must be set before jax init)."""
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", _SHMAP_WARM_PROG], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "SHMAP WARM OK" in out.stdout
