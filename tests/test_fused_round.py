"""Fused whole-round megakernel (kernels/round + round='fused').

Four layers, mirroring tests/test_phase_kernels.py for the fused round:
  1. kernel-vs-ref property tests (via tests/_hyp.py): one megakernel
     dispatch — merge + local fixpoint + send pack, rescue included —
     matches the pure-jnp oracle on random shard states for every shard,
     bucket AND dense, including deliberately-too-few in-kernel sweeps
  2. e2e bit-identity: round='fused' reproduces the staged pipeline
     EXACTLY — distances, q_rounds, q_relaxations, msgs — across
     bucket/pmin/a2a_dense x K in {1, 3}, in sim and (subprocess) shmap,
     and under an active FaultPlan with toka3 + anti-entropy resend
  3. dispatch accounting: stats.n_dispatches = 2 x rounds fused vs
     4 x rounds staged
  4. layout fallback: round='fused' degrades to the staged pipeline with
     a ONE-TIME warning when build_shards skipped the tiled layouts

The q_relaxations baseline is the staged pipeline with
local_solver='pallas': relaxation COUNTS are sweep-schedule dependent
(the megakernel replicates the batched Gauss–Seidel schedule), while
distances/rounds/msgs are schedule-independent (the fixpoint is unique
and send floors are monotone) and so are also asserted against the plain
XLA bellman baseline.
"""
import os
import subprocess
import sys
import textwrap
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import given, settings, strategies as st
from repro.core import SsspConfig, build_shards, phases, solve_sim_batch
from repro.core.faults import FaultPlan
from repro.graph import dijkstra_reference, random_graph
from repro.kernels.round import (fused_round_pallas, fused_round_ref,
                                 fused_round_rescue)

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

EXCHANGES = ("bucket", "pmin", "a2a_dense")
INF = np.float32(np.inf)


def _sources(g, nq, seed=17):
    rng = np.random.default_rng(seed)
    return sorted(int(s) for s in
                  rng.choice(g.n_vertices, size=nq, replace=False))


# ---------------------------------------------- kernel vs ref oracle ----

@settings(max_examples=4, deadline=None)
@given(n=st.integers(60, 220), mult=st.integers(2, 5),
       p=st.integers(2, 5), nq=st.integers(1, 3), seed=st.integers(0, 99),
       n_sweeps=st.integers(2, 8))
def test_fused_kernel_matches_ref(n, mult, p, nq, seed, n_sweeps):
    """One megakernel dispatch (plus rescue when the in-kernel sweep
    budget was too small) is bit-identical to the merge -> Jacobi
    fixpoint -> segment-min-pack oracle, on every shard, for random
    mid-solve state honoring the carry contracts."""
    g = random_graph(n=n, m=n * mult, seed=seed)
    sh = build_shards(g, p)
    block = sh.block
    rng = np.random.default_rng(seed * 31 + nq)
    for part in range(p):
        s0 = jax.tree_util.tree_map(lambda x: x[part], sh)
        S = s0.slot_owner.shape[0]
        e_loc, e_cut = s0.loc_src.shape[0], s0.cut_src.shape[0]
        dist = np.where(rng.random((nq, block)) < 0.3, INF,
                        (rng.random((nq, block)) * 10).astype(np.float32))
        front = rng.random((nq, block)) < 0.2
        live = rng.random(nq) < 0.8
        ridx = np.asarray(s0.recv_idx)
        inc_b = np.where(rng.random((nq,) + ridx.shape) < 0.5, INF,
                         (rng.random((nq,) + ridx.shape) * 10)
                         .astype(np.float32))
        inc_b = np.where((ridx == block)[None], INF, inc_b)  # routed only
        last = np.where(rng.random((nq, S)) < 0.5, INF,
                        (rng.random((nq, S)) * 10).astype(np.float32))
        last = np.where(np.asarray(s0.slot_valid)[None], last, INF)
        prn_loc = rng.random(e_loc) < 0.15
        prn_cut = rng.random(e_cut) < 0.15

        for dense in (False, True):
            if dense:
                inc = np.where(rng.random((nq, block)) < 0.5, INF,
                               (rng.random((nq, block)) * 10)
                               .astype(np.float32))
            else:
                inc = inc_b.reshape(nq, -1)
            nd, sv, nl, nrel, sends, resid = fused_round_pallas(
                jnp.asarray(dist), jnp.asarray(front), jnp.asarray(live),
                jnp.asarray(inc), jnp.asarray(last), s0.slot_valid,
                s0.relax_layout, s0.send_layout, s0.merge_layout,
                jnp.asarray(prn_loc), jnp.asarray(prn_cut), vb=sh.rx_vb,
                sb=sh.tx_sb, n_sweeps=n_sweeps, dense=dense)
            if bool(jnp.any(resid > 0)):
                nd, sv, nl, extra, sends = fused_round_rescue(
                    nd, resid, jnp.asarray(last), s0.slot_valid,
                    s0.relax_layout, s0.send_layout, jnp.asarray(prn_loc),
                    jnp.asarray(prn_cut), vb=sh.rx_vb, sb=sh.tx_sb,
                    n_sweeps=n_sweeps)
            rd, rsv, rnl, rsends = fused_round_ref(
                jnp.asarray(dist), jnp.asarray(front), jnp.asarray(live),
                jnp.asarray(inc), s0.recv_idx, jnp.asarray(last),
                s0.slot_valid, s0.loc_src, s0.loc_dst, s0.loc_w,
                jnp.asarray(prn_loc), s0.cut_src, s0.cut_seg, s0.cut_w,
                jnp.asarray(prn_cut), dense=dense)
            tag = f"part={part} dense={dense}"
            np.testing.assert_array_equal(np.asarray(nd), np.asarray(rd),
                                          err_msg=f"dist {tag}")
            np.testing.assert_array_equal(np.asarray(sv), np.asarray(rsv),
                                          err_msg=f"send_val {tag}")
            np.testing.assert_array_equal(np.asarray(nl), np.asarray(rnl),
                                          err_msg=f"new_last {tag}")
            np.testing.assert_array_equal(np.asarray(sends),
                                          np.asarray(rsends),
                                          err_msg=f"sends {tag}")


# ------------------------------------------------- e2e bit-identity ----

@pytest.mark.parametrize("nq", [1, 3])
def test_fused_round_bit_identical_sim(nq):
    """round='fused' is BIT-identical to the staged pipeline for every
    exchange mode: distances + q_rounds + msgs against BOTH staged
    baselines, q_relaxations against the pallas local solver (same
    Gauss–Seidel schedule), and n_dispatches records the 4 -> 2 fusion."""
    g = random_graph(n=180, m=700, seed=21)
    sh = build_shards(g, 5)
    sources = _sources(g, nq)
    refs = np.stack([dijkstra_reference(g, s) for s in sources])
    for ex in EXCHANGES:
        d_pal, s_pal = solve_sim_batch(
            sh, sources, SsspConfig(exchange=ex, toka="toka2",
                                    local_solver="pallas"))
        d_xla, s_xla = solve_sim_batch(
            sh, sources, SsspConfig(exchange=ex, toka="toka2"))
        d_fus, s_fus = solve_sim_batch(
            sh, sources, SsspConfig(exchange=ex, toka="toka2",
                                    round="fused"))
        np.testing.assert_allclose(d_fus, refs, rtol=1e-5, atol=1e-4)
        np.testing.assert_array_equal(np.asarray(d_fus), np.asarray(d_pal))
        np.testing.assert_array_equal(np.asarray(d_fus), np.asarray(d_xla))
        for base in (s_pal, s_xla):
            assert int(s_fus.rounds) == int(base.rounds), ex
            np.testing.assert_array_equal(np.asarray(s_fus.q_rounds),
                                          np.asarray(base.q_rounds),
                                          err_msg=ex)
            assert int(s_fus.msgs_sent) == int(base.msgs_sent), ex
            assert int(s_fus.msgs_recv) == int(base.msgs_recv), ex
        np.testing.assert_array_equal(np.asarray(s_fus.q_relaxations),
                                      np.asarray(s_pal.q_relaxations),
                                      err_msg=ex)
        # the satellite counter: dispatch volume halves per round
        assert int(s_fus.n_dispatches) == 2 * int(s_fus.rounds)
        assert int(s_pal.n_dispatches) == 4 * int(s_pal.rounds)


def test_fused_round_few_sweeps_rescue_bit_identical():
    """pallas_sweeps=1 forces the rescue continuation on nearly every
    round; the results must not move (the rescue replays the staged outer
    relax loop and re-packs against the original last_sent)."""
    g = random_graph(n=150, m=600, seed=4)
    sh = build_shards(g, 4)
    sources = _sources(g, 2, seed=3)
    d_base, s_base = solve_sim_batch(
        sh, sources, SsspConfig(toka="toka2", local_solver="pallas",
                                pallas_sweeps=1))
    d_fus, s_fus = solve_sim_batch(
        sh, sources, SsspConfig(toka="toka2", round="fused",
                                pallas_sweeps=1))
    np.testing.assert_array_equal(np.asarray(d_fus), np.asarray(d_base))
    np.testing.assert_array_equal(np.asarray(s_fus.q_rounds),
                                  np.asarray(s_base.q_rounds))
    np.testing.assert_array_equal(np.asarray(s_fus.q_relaxations),
                                  np.asarray(s_base.q_relaxations))
    assert int(s_fus.msgs_sent) == int(s_base.msgs_sent)


def test_fused_round_faults_bit_identical():
    """The fault-injection wrapper and toka3 compose around the fused
    exchange boundary unchanged: same PRNG placement, same delivery
    accounting, same anti-entropy resend windows — every stat matches the
    staged pipeline under an aggressive FaultPlan."""
    g = random_graph(n=150, m=600, seed=9)
    sh = build_shards(g, 4)
    sources = _sources(g, 2, seed=11)
    refs = np.stack([dijkstra_reference(g, s) for s in sources])
    fp = FaultPlan(drop=0.2, delay=0.1, duplicate=0.05, seed=3, max_delay=3,
                   resend_period=4)
    for ex in ("bucket", "a2a_dense"):
        d_base, s_base = solve_sim_batch(
            sh, sources, SsspConfig(exchange=ex, toka="toka3",
                                    local_solver="pallas", faults=fp))
        d_fus, s_fus = solve_sim_batch(
            sh, sources, SsspConfig(exchange=ex, toka="toka3",
                                    round="fused", faults=fp))
        np.testing.assert_allclose(d_fus, refs, rtol=1e-5, atol=1e-4)
        np.testing.assert_array_equal(np.asarray(d_fus), np.asarray(d_base))
        for f in ("rounds", "q_rounds", "q_relaxations", "msgs_sent",
                  "msgs_recv", "stale_merges", "resends"):
            np.testing.assert_array_equal(
                np.asarray(getattr(s_fus, f)),
                np.asarray(getattr(s_base, f)), err_msg=f"{ex} {f}")


_SHMAP_FUSED_PROG = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    os.environ["JAX_PLATFORMS"] = "cpu"
    import numpy as np
    from repro import compat
    from repro.core import SsspConfig, build_shards, solve_shmap_batch
    from repro.graph import random_graph, dijkstra_reference

    g = random_graph(n=180, m=700, seed=21)
    sh = build_shards(g, 4)
    mesh = compat.make_mesh((4,), ("d",))
    rng = np.random.default_rng(17)
    sources = sorted(int(s) for s in
                     rng.choice(g.n_vertices, size=3, replace=False))
    refs = np.stack([dijkstra_reference(g, s) for s in sources])
    for ex in ("bucket", "pmin", "a2a_dense"):
        cfg_s = SsspConfig(exchange=ex, local_solver="pallas")
        cfg_f = SsspConfig(exchange=ex, round="fused")
        ds, ss = solve_shmap_batch(sh, sources, cfg_s, mesh, ("d",))
        df, sf = solve_shmap_batch(sh, sources, cfg_f, mesh, ("d",))
        assert np.allclose(df, refs, 1e-5, 1e-4), ex
        assert (np.asarray(df) == np.asarray(ds)).all(), ex
        for f in ("rounds", "q_rounds", "q_relaxations", "msgs_sent",
                  "msgs_recv"):
            a, b = np.asarray(getattr(sf, f)), np.asarray(getattr(ss, f))
            assert (a == b).all(), (ex, f)
        assert int(sf.n_dispatches) == 2 * int(sf.rounds), ex
        assert int(ss.n_dispatches) == 4 * int(ss.rounds), ex
    print("SHMAP FUSED ROUND OK")
""")


def test_fused_round_bit_identical_shmap():
    """Same bit-identity under shard_map with real collectives on a
    spoofed 4-device mesh (subprocess: device count must be set before
    jax initializes)."""
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", _SHMAP_FUSED_PROG], env=env,
                         capture_output=True, text=True, timeout=1800)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "SHMAP FUSED ROUND OK" in out.stdout


# ------------------------------------------------- layout fallback ----

def test_fused_round_falls_back_with_one_time_warning():
    """Without the tiled layouts the fused backend degrades to the staged
    pipeline (default xla phases) with exactly ONE warning, once."""
    g = random_graph(150, 600, seed=9)
    sh = build_shards(g, 4, relax_layout=False, comm_layout=False)
    cfg = SsspConfig(round="fused")
    phases._WARNED.clear()
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        d, stats = solve_sim_batch(sh, [0], cfg)
    msgs = [str(w.message) for w in rec]
    assert len(msgs) == 1 and "round='fused' falling back" in msgs[0], msgs
    np.testing.assert_allclose(d[0], dijkstra_reference(g, 0),
                               rtol=1e-5, atol=1e-4)
    # the fallback really is the staged pipeline: 4 dispatches per round
    assert int(stats.n_dispatches) == 4 * int(stats.rounds)
    with warnings.catch_warnings(record=True) as rec2:
        warnings.simplefilter("always")
        solve_sim_batch(sh, [1], cfg)
    assert not rec2
