"""Deferred (async) exchange: double-buffered delivery, overlap accounting.

The asynchronous-mode contract under test (paper §III: relax/communicate
without a per-round barrier; safety from the monotone idempotent
scatter-min merge):

  1. every deferred exchange (``async``/``async_bucket`` double-buffered
     all-to-all, ``async_ppermute`` bidirectional ring streaming) reaches
     a fixpoint BIT-IDENTICAL to the synchronous ``bucket`` exchange, for
     staged and fused rounds, K in {1, 3} — only round counts differ
  2. the property holds for ARBITRARY delivery lag (``async_lag`` >= 1)
     and under every ToKa termination detector: in-flight payload sets
     pending bits, so no detector declares quiescence over the wire
  3. FaultPlan regimes compose with the lag: faults inject at DELIVERY
     time, anti-entropy resends ride the pipe, and the run still heals to
     the fault-free baseline
  4. the stats tell the overlap story: deferred runs report
     ``overlap_rounds``/``stale_merges``/``bytes_moved`` (sync runs pin
     them at zero), and ``bytes_moved`` prices only the payload columns
     that actually carried an improvement
  5. the sim backend is a bit-level oracle of shmap: distances AND round
     counts AND the new counters agree across backends (subprocess on a
     spoofed 4-device mesh)
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest
from _hyp import given, settings, strategies as st

from repro.core import (FaultPlan, SsspConfig, build_shards, solve_sim_batch)
from repro.graph import dijkstra_reference, random_graph

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

ASYNC_EXCHANGES = ("async", "async_bucket", "async_ppermute")
TOKAS = ("toka0", "toka1", "toka2", "toka3")


@pytest.fixture(scope="module")
def fixture_graph():
    g = random_graph(n=180, m=720, seed=3)
    return g, build_shards(g, 4)


def _baseline(sh, sources, **cfg_kw):
    d, s = solve_sim_batch(sh, sources, SsspConfig(exchange="bucket", **cfg_kw))
    return np.asarray(d), s


# ------------------------------------------------ bit-identity matrix ----

def test_async_bit_identity_matrix(fixture_graph):
    """All three deferred exchanges x staged/fused x K in {1,3} solve to
    the exact synchronous distances (and those match Dijkstra); deferred
    runs take MORE rounds (the price of the lag) and report bytes."""
    g, sh = fixture_graph
    srcs = [0, 7, 11]
    refs = np.stack([dijkstra_reference(g, s) for s in srcs])
    for k in (1, 3):
        for rnd in ("staged", "fused"):
            base, sb = _baseline(sh, srcs[:k], round=rnd)
            assert np.allclose(base, refs[:k], rtol=1e-5, atol=1e-4)
            for ex in ASYNC_EXCHANGES:
                d, s = solve_sim_batch(
                    sh, srcs[:k], SsspConfig(round=rnd, exchange=ex))
                assert np.array_equal(np.asarray(d), base), (k, rnd, ex)
                assert int(s.rounds) > int(sb.rounds), (k, rnd, ex)
                assert int(s.bytes_moved) > 0, (k, rnd, ex)


# ------------------------------------------- lag + toka property test ----

_PROP_CACHE = {}


def _prop_graph():
    # one graph/shards pair for every drawn example: the engine's
    # compiled-round cache is keyed on the shards OBJECT, so rebuilding
    # per example would recompile per example
    if "gs" not in _PROP_CACHE:
        g = random_graph(n=180, m=720, seed=3)
        _PROP_CACHE["gs"] = (g, build_shards(g, 4))
    return _PROP_CACHE["gs"]


@settings(max_examples=8, deadline=None)
@given(lag=st.integers(min_value=1, max_value=3),
       toka_i=st.integers(min_value=0, max_value=3),
       src=st.integers(min_value=0, max_value=179))
def test_async_lag_reaches_sync_fixpoint(lag, toka_i, src):
    """Property: an arbitrary ``lag``-round-delayed delivery schedule
    reaches the SAME fixpoint as synchronous delivery under EVERY
    termination detector — the monotone min merge is lag-independent, and
    the in-flight pending bits keep every detector honest."""
    g, sh = _prop_graph()
    srcs = sorted({src, (src * 7 + 13) % g.n_vertices, 11})
    toka = TOKAS[toka_i]
    base, sb = _baseline(sh, srcs, toka=toka)
    d, s = solve_sim_batch(
        sh, srcs, SsspConfig(exchange="async", async_lag=lag, toka=toka))
    assert np.array_equal(np.asarray(d), base), (lag, toka)
    assert int(s.rounds) > int(sb.rounds), (lag, toka)


def test_async_all_tokas_all_backends(fixture_graph):
    """Every deferred exchange terminates correctly under every detector
    (the non-property, full-matrix complement of the test above)."""
    _, sh = fixture_graph
    srcs = [0, 7]
    for toka in TOKAS:
        base, _ = _baseline(sh, srcs, toka=toka)
        for ex in ASYNC_EXCHANGES:
            d, _ = solve_sim_batch(
                sh, srcs, SsspConfig(exchange=ex, toka=toka))
            assert np.array_equal(np.asarray(d), base), (toka, ex)


# ------------------------------------------------- faults compose ----

def test_async_faults_heal_to_baseline(fixture_graph):
    """FaultPlan injection at delivery time + anti-entropy resend compose
    with the lag: drops/delays/dups/reorders on top of deferred delivery
    still converge bit-identical to the fault-free synchronous solve."""
    _, sh = fixture_graph
    srcs = [0, 7]
    base, _ = _baseline(sh, srcs)
    plan = FaultPlan(drop=0.05, delay=0.1, duplicate=0.05, reorder=0.05,
                     seed=9, resend_period=4)
    for rnd in ("staged", "fused"):
        for ex in ("async", "async_ppermute"):
            cfg = SsspConfig(round=rnd, exchange=ex, toka="toka3",
                             faults=plan)
            d, s = solve_sim_batch(sh, srcs, cfg)
            assert np.array_equal(np.asarray(d), base), (rnd, ex)
            assert int(np.asarray(s.resends).sum()) > 0, (rnd, ex)


# ------------------------------------------------- stats contract ----

def test_async_stats_overlap_stale_bytes(fixture_graph):
    """Sync exchanges pin the new counters at zero; deferred runs count
    stale (late-delivered improving) merges and wire bytes. Overlap needs
    off-phase work to exist: single-wave lag-1 double buffering alternates
    compute and delivery rounds in the lock-step sim (overlap 0 is the
    honest measurement), while ring streaming (``async_ppermute``) and
    fault-delayed traffic genuinely coexist with the relax."""
    _, sh = fixture_graph
    srcs = [0, 7, 11]
    _, s_sync = _baseline(sh, srcs)
    assert int(s_sync.overlap_rounds) == 0
    assert int(np.asarray(s_sync.stale_merges).sum()) == 0

    _, s_async = solve_sim_batch(sh, srcs, SsspConfig(exchange="async"))
    assert int(np.asarray(s_async.stale_merges).sum()) > 0
    assert int(s_async.bytes_moved) > 0

    _, s_ring = solve_sim_batch(
        sh, srcs, SsspConfig(exchange="async_ppermute"))
    assert int(s_ring.overlap_rounds) > 0

    plan = FaultPlan(delay=0.3, seed=5)
    _, s_fd = solve_sim_batch(
        sh, srcs, SsspConfig(exchange="async", faults=plan))
    assert int(s_fd.overlap_rounds) > 0


def test_a2a_dense_bytes_priced_and_masked(fixture_graph):
    """Satellite: the dense all-to-all no longer ships every column —
    unimproved (query, destination) columns are masked to +inf before the
    collective and ``bytes_moved`` prices only the used ones, so the dense
    wire cost lands well under the worst case and the masked payload still
    solves bit-identical."""
    _, sh = fixture_graph
    srcs = [0, 7]
    base, _ = _baseline(sh, srcs)
    d, s = solve_sim_batch(sh, srcs, SsspConfig(exchange="a2a_dense"))
    assert np.array_equal(np.asarray(d), base)
    worst = 4 * sh.block * len(srcs) * sh.n_parts * sh.n_parts \
        * int(s.rounds)
    assert 0 < int(s.bytes_moved) < worst


# ------------------------------------------------- validation ----

def test_async_config_validation():
    with pytest.raises(ValueError, match="async_lag"):
        SsspConfig(exchange="async", async_lag=0)
    with pytest.raises(ValueError, match="async_lag"):
        SsspConfig(exchange="bucket", async_lag=2)
    with pytest.raises(ValueError, match="async_lag"):
        SsspConfig(exchange="async_ppermute", async_lag=2)
    SsspConfig(exchange="async_bucket", async_lag=3)  # valid


# ------------------------------------------------- shmap parity ----

_SHMAP_PROG = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    os.environ["JAX_PLATFORMS"] = "cpu"
    import numpy as np
    from repro import compat
    from repro.core import (SsspConfig, build_shards, solve_shmap_batch,
                            solve_sim_batch)
    from repro.graph import random_graph

    g = random_graph(n=180, m=720, seed=3)
    sh = build_shards(g, 4)
    mesh = compat.make_mesh((4,), ("d",))
    srcs = [0, 7, 11]
    for k in (1, 3):
        for rnd in ("staged", "fused"):
            db, _ = solve_shmap_batch(
                sh, srcs[:k], SsspConfig(round=rnd), mesh, ("d",))
            base = np.asarray(db)
            for ex in ("async", "async_bucket", "async_ppermute"):
                cfg = SsspConfig(round=rnd, exchange=ex)
                d2, s2 = solve_shmap_batch(sh, srcs[:k], cfg, mesh, ("d",))
                assert np.array_equal(np.asarray(d2), base), (k, rnd, ex)
                ds, ss = solve_sim_batch(sh, srcs[:k], cfg)
                assert np.array_equal(np.asarray(ds), np.asarray(d2))
                for f in ("rounds", "q_rounds", "overlap_rounds",
                          "bytes_moved", "msgs_sent", "msgs_recv"):
                    a = np.asarray(getattr(s2, f))
                    b = np.asarray(getattr(ss, f))
                    assert (a == b).all(), (k, rnd, ex, f)
                assert (np.asarray(s2.stale_merges)
                        == np.asarray(ss.stale_merges)).all(), (k, rnd, ex)
    print("ASYNC SHMAP PARITY OK")
""")


def test_async_shmap_matches_sim_bitwise():
    """The sim is a bit-level oracle of the shmap deferred exchanges:
    distances, round counts, and the overlap/stale/bytes counters agree
    exactly on a spoofed 4-device mesh."""
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", _SHMAP_PROG], env=env,
                         capture_output=True, text=True, timeout=1800)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "ASYNC SHMAP PARITY OK" in out.stdout


# --------------------------------------- acceptance matrix (slow) ----

_ACCEPT_PROG = textwrap.dedent("""
    import numpy as np
    from repro.core import (FaultPlan, SsspConfig, build_shards,
                            solve_sim_batch)
    from repro.graph import rmat_graph, road_grid_graph, dijkstra_reference

    graphs = {
        "graph1-like": rmat_graph(scale=10, edge_factor=2, seed=1),
        "graph2-like": road_grid_graph(side=32, seed=2),
        "graph3-like": rmat_graph(scale=8, edge_factor=16, seed=3),
    }
    plans = {
        "clean": None,
        "drop": FaultPlan(drop=0.2, seed=11, resend_period=4),
        "delay": FaultPlan(delay=0.3, seed=12),
    }
    rng = np.random.default_rng(5)
    for name, g in graphs.items():
        srcs = sorted(int(s) for s in
                      rng.choice(g.n_vertices, size=3, replace=False))
        refs = np.stack([dijkstra_reference(g, s) for s in srcs])
        sh = build_shards(g, 8, enumerate_triangles=False)
        base, _ = solve_sim_batch(
            sh, srcs, SsspConfig(exchange="bucket", prune_online=False))
        base = np.asarray(base)
        assert np.allclose(base, refs, 1e-5, 1e-4), name
        for rnd in ("staged", "fused"):
            for pname, plan in plans.items():
                cfg = SsspConfig(round=rnd, exchange="async",
                                 toka="toka3", prune_online=False,
                                 faults=plan)
                d, s = solve_sim_batch(sh, srcs, cfg)
                assert np.array_equal(np.asarray(d), base), \\
                    (name, rnd, pname)
        cfgp = SsspConfig(exchange="async_ppermute", prune_online=False)
        d, s = solve_sim_batch(sh, srcs, cfgp)
        assert np.array_equal(np.asarray(d), base), (name, "ppermute")
        assert int(s.overlap_rounds) > 0, (name, "ppermute")
        print(f"{name} OK")
    print("ASYNC MATRIX OK")
""")


@pytest.mark.slow
def test_async_acceptance_matrix():
    """Acceptance (nightly): async exchanges x staged/fused x FaultPlan
    regimes solve bit-identical to the synchronous baseline on all three
    bench-graph families at P=8."""
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    out = subprocess.run([sys.executable, "-c", _ACCEPT_PROG], env=env,
                         capture_output=True, text=True, timeout=3000)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "ASYNC MATRIX OK" in out.stdout
