"""Graph generator invariants (the paper's §IV setup)."""
import numpy as np
from _hyp import given, settings, strategies as st

from repro.graph import rmat_graph, road_grid_graph, random_graph
from repro.graph.structure import graph_to_numpy


@settings(max_examples=10, deadline=None)
@given(scale=st.integers(4, 9), ef=st.integers(2, 12), seed=st.integers(0, 99))
def test_rmat_weights_in_paper_range(scale, ef, seed):
    g = rmat_graph(scale=scale, edge_factor=ef, seed=seed)
    src, dst, w = graph_to_numpy(g)
    assert (w >= 1.0).all() and (w < 20.0).all()       # paper: U[1, 20)
    assert (src < g.n_vertices).all() and (dst < g.n_vertices).all()
    assert (src != dst).all()                           # no self loops


@settings(max_examples=10, deadline=None)
@given(scale=st.integers(4, 8), seed=st.integers(0, 99))
def test_rmat_undirected_symmetry(scale, seed):
    g = rmat_graph(scale=scale, edge_factor=4, seed=seed)
    src, dst, w = graph_to_numpy(g)
    fwd = set(zip(src.tolist(), dst.tolist()))
    assert all((d, s) in fwd for s, d in list(fwd)[:200])


@settings(max_examples=10, deadline=None)
@given(side=st.integers(4, 24), seed=st.integers(0, 99))
def test_road_grid_degree_bounded(side, seed):
    """Road networks have bounded degree (paper graph2: max degree 9)."""
    g = road_grid_graph(side=side, seed=seed)
    src, dst, _ = graph_to_numpy(g)
    deg = np.bincount(src, minlength=g.n_vertices)
    assert deg.max() <= 8


@settings(max_examples=10, deadline=None)
@given(n=st.integers(10, 200), m=st.integers(10, 500), seed=st.integers(0, 99))
def test_random_graph_connectivity_chain(n, m, seed):
    from repro.graph import dijkstra_reference
    g = random_graph(n=n, m=m, seed=seed, ensure_connected_from=0)
    dist = dijkstra_reference(g, 0)
    assert np.isfinite(dist).all()       # chain guarantees reachability


def test_dedup_keeps_min_weight():
    from repro.graph.structure import csr_from_coo
    src = np.array([0, 0, 0])
    dst = np.array([1, 1, 1])
    w = np.array([5.0, 2.0, 9.0], np.float32)
    g = csr_from_coo(src, dst, w, 2)
    assert g.n_edges == 1
    assert float(g.weight[0]) == 2.0
