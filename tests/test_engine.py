"""SsspEngine session API: K-bucketed compile reuse, padding parity,
streaming submit/drain, legacy-wrapper delegation.

The engine's contract under test:
  1. one compiled program per (K-bucket, cfg) serves ARBITRARY source
     batches — asserted by the engine's trace counters, sim and shmap
  2. padded-bucket results bit-match the unpadded reference (padded rows
     start converged and never touch any statistic)
  3. the five legacy entry points delegate to a cached engine and keep
     bit-identical results
  4. submit/drain coalesces streaming arrivals into bucketed batches
     without splitting a submission
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import (QueryResult, SsspConfig, SsspEngine, bucket_k,
                        build_shards, engine_for, solve_sim, solve_sim_batch)
from repro.graph import dijkstra_reference, random_graph

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(scope="module")
def graph_and_shards():
    g = random_graph(n=180, m=700, seed=21)
    return g, build_shards(g, 5)


def _refs(g, sources):
    return np.stack([dijkstra_reference(g, s) for s in sources])


# ------------------------------------------------------- bucket policy ----

def test_bucket_policy_powers_of_two():
    assert [bucket_k(k) for k in (1, 2, 3, 4, 5, 8, 9, 16)] == \
        [1, 2, 4, 4, 8, 8, 16, 16]
    with pytest.raises(ValueError):
        bucket_k(0)


def test_engine_build_from_graph_and_shards(graph_and_shards):
    g, sh = graph_and_shards
    eng = SsspEngine.build(sh)
    assert eng.n_vertices == g.n_vertices and eng.n_parts == 5
    eng_g = SsspEngine.build(g, n_parts=3, enumerate_triangles=False)
    assert eng_g.n_parts == 3
    res = eng_g.solve([0])
    np.testing.assert_allclose(res.dist[0], dijkstra_reference(g, 0),
                               rtol=1e-5, atol=1e-4)
    with pytest.raises(ValueError, match="shard build options"):
        SsspEngine.build(sh, n_parts=3, enumerate_triangles=False)
    with pytest.raises(ValueError, match="mesh"):
        SsspEngine.build(sh, backend="shmap")
    with pytest.raises(ValueError, match="backend"):
        SsspEngine.build(sh, backend="mpi")


# ------------------------------------------- compile reuse (tentpole) ----

def test_trace_reuse_same_bucket_sim(graph_and_shards):
    """Two solves with DIFFERENT source sets in the same K-bucket trigger
    exactly one trace; a new bucket shape traces once more."""
    g, sh = graph_and_shards
    eng = SsspEngine.build(sh)
    r1 = eng.solve([3, 17, 99])          # K=3 -> bucket 4, cold
    assert r1.compiled and r1.bucket_k == 4
    assert eng.trace_counts == {4: 1}
    r2 = eng.solve([120, 5, 66, 8])      # K=4 -> same bucket, warm
    assert not r2.compiled and r2.compile_s == 0.0
    assert eng.trace_counts == {4: 1}
    r3 = eng.solve([12])                 # K=1 -> new bucket
    assert r3.compiled and r3.bucket_k == 1
    assert eng.trace_counts == {4: 1, 1: 1}
    refs = _refs(g, [120, 5, 66, 8])
    np.testing.assert_allclose(r2.dist, refs, rtol=1e-5, atol=1e-4)


def test_padded_bucket_bitmatches_unpadded_reference(graph_and_shards):
    """Padded rows (converged from round 0) must not perturb real queries:
    the padded-bucket solve bit-matches the unpadded reference, distances
    AND per-query stats."""
    g, sh = graph_and_shards
    sources = [3, 17, 99]
    eng = SsspEngine.build(sh, SsspConfig(prune_online=False))
    padded = eng.solve(sources)               # rides the K=4 bucket
    exact = eng.solve(sources, bucket=False)  # K=3, no padding
    assert padded.bucket_k == 4 and exact.bucket_k == 3
    assert np.array_equal(padded.dist, exact.dist)
    assert np.array_equal(padded.q_rounds, exact.q_rounds)
    assert np.array_equal(padded.q_relaxations, exact.q_relaxations)
    for field in ("rounds", "relaxations", "msgs_sent", "msgs_recv"):
        assert int(getattr(padded.stats, field)) == \
            int(getattr(exact.stats, field)), field
    # and both match the legacy wrapper (which itself rides the engine)
    d, st = solve_sim_batch(sh, sources, SsspConfig(prune_online=False))
    assert np.array_equal(d, padded.dist)
    assert np.array_equal(np.asarray(st.q_rounds), padded.q_rounds)


def test_query_result_structure(graph_and_shards):
    g, sh = graph_and_shards
    eng = SsspEngine.build(sh)
    res = eng.solve([7, 11])
    assert isinstance(res, QueryResult)
    assert res.sources == (7, 11) and res.backend == "sim"
    assert res.dist.shape == (2, g.n_vertices)
    assert res.q_rounds.shape == (2,) and res.q_relaxations.shape == (2,)
    assert res.wall_s > 0 and res.compiled and res.compile_s > 0
    warm = eng.solve([1, 2])
    assert warm.compile_s == 0.0 and not warm.compiled
    with pytest.raises(ValueError, match="out of range"):
        eng.solve([g.n_vertices])
    with pytest.raises(ValueError, match="at least one source"):
        eng.solve([])


def test_warmup_precompiles(graph_and_shards):
    _, sh = graph_and_shards
    eng = SsspEngine.build(sh)
    cold_s = eng.warmup(3)
    assert cold_s > 0 and eng.trace_counts == {4: 1}
    res = eng.solve([9, 10, 11])
    assert not res.compiled
    # an already-warm bucket short-circuits: no solve is run at all
    served = eng.batches_served
    assert eng.warmup(4) == 0.0
    assert eng.batches_served == served


# ------------------------------------------------ legacy delegation ----

def test_wrappers_share_one_engine(graph_and_shards):
    """solve_sim / solve_sim_batch ride ONE cached engine per (shards,
    cfg): repeated calls with new sources add no traces."""
    _, sh = graph_and_shards
    cfg = SsspConfig(exchange="pmin")
    solve_sim_batch(sh, [0, 1], cfg)
    eng = engine_for(sh, cfg)
    assert eng.trace_counts == {2: 1}
    solve_sim_batch(sh, [40, 41], cfg)
    solve_sim(sh, 7, cfg)
    assert eng.trace_counts == {2: 1, 1: 1}
    solve_sim(sh, 8, cfg)
    assert eng.trace_counts == {2: 1, 1: 1}


# ---------------------------------------------------- submit / drain ----

def test_submit_drain_coalesces(graph_and_shards):
    g, sh = graph_and_shards
    eng = SsspEngine.build(sh, max_bucket=4)
    hs = [eng.submit(3), eng.submit([17, 99]), eng.submit(120), eng.submit(5)]
    assert eng.pending == 4 and not hs[0].done
    results = eng.drain()
    assert eng.pending == 0 and len(results) == 4
    # max_bucket=4: handles coalesce as [1+2+1] then [1] — never split
    assert [r.bucket_k for r in results] == [4, 4, 4, 1]
    for h in hs:
        assert h.done
        refs = _refs(g, h.sources)
        np.testing.assert_allclose(h.result().dist, refs, rtol=1e-5,
                                   atol=1e-4)
        assert h.result().q_rounds.shape == (len(h.sources),)


def test_handle_result_drains_on_demand(graph_and_shards):
    g, sh = graph_and_shards
    eng = SsspEngine.build(sh)
    h = eng.submit([33, 44])
    res = h.result()            # implicit drain
    assert eng.pending == 0 and h.done
    np.testing.assert_allclose(res.dist, _refs(g, [33, 44]), rtol=1e-5,
                               atol=1e-4)
    with pytest.raises(ValueError, match="out of range"):
        eng.submit(g.n_vertices + 1)   # validated at submission
    with pytest.raises(ValueError, match="at least one source"):
        eng.submit([])                 # an empty batch can never drain
    assert eng.pending == 0


def test_drain_requeues_on_failure(graph_and_shards, monkeypatch):
    """A solve failure mid-drain must not lose submissions: the failing
    batch and everything after it go back on the queue."""
    g, sh = graph_and_shards
    eng = SsspEngine.build(sh, max_bucket=2)
    h1, h2, h3 = eng.submit(1), eng.submit(2), eng.submit(3)  # two batches
    monkeypatch.setattr(eng, "solve",
                        lambda *a, **kw: (_ for _ in ()).throw(
                            RuntimeError("backend down")))
    with pytest.raises(RuntimeError, match="backend down"):
        eng.drain()
    assert eng.pending == 3 and not h1.done
    monkeypatch.undo()
    eng.drain()
    for h, s in ((h1, 1), (h2, 2), (h3, 3)):
        assert h.done
        np.testing.assert_allclose(h.result().dist[0],
                                   dijkstra_reference(g, s),
                                   rtol=1e-5, atol=1e-4)


def test_oversized_submission_rides_own_bucket(graph_and_shards):
    g, sh = graph_and_shards
    eng = SsspEngine.build(sh, max_bucket=2)
    h = eng.submit([1, 2, 3])    # larger than max_bucket: not split
    (res,) = eng.drain()
    assert res.bucket_k == 4 and res.sources == (1, 2, 3)
    np.testing.assert_allclose(res.dist, _refs(g, [1, 2, 3]), rtol=1e-5,
                               atol=1e-4)
    assert h.result() is res


# -------------------------------------------------- shmap backend ----

_SHMAP_ENGINE_PROG = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    os.environ["JAX_PLATFORMS"] = "cpu"
    import numpy as np
    from repro import compat
    from repro.core import (SsspConfig, SsspEngine, build_shards, engine_for,
                            solve_shmap_batch)
    from repro.graph import random_graph, dijkstra_reference

    g = random_graph(n=180, m=700, seed=21)
    sh = build_shards(g, 4)
    mesh = compat.make_mesh((4,), ("d",))
    eng = SsspEngine.build(sh, SsspConfig(), backend="shmap", mesh=mesh,
                           axis_names=("d",))

    # compile reuse: one whole-solve program per K-bucket, sources traced
    r1 = eng.solve([3, 17, 99])
    assert r1.compiled and r1.bucket_k == 4 and eng.trace_counts == {4: 1}
    r2 = eng.solve([120, 5, 66])          # new sources, same bucket
    assert not r2.compiled and eng.trace_counts == {4: 1}, eng.trace_counts
    refs = np.stack([dijkstra_reference(g, s) for s in [120, 5, 66]])
    assert np.allclose(r2.dist, refs, 1e-5, 1e-4)

    # padded bucket bit-matches the unpadded reference
    exact = eng.solve([3, 17, 99], bucket=False)
    assert np.array_equal(r1.dist, exact.dist)
    assert np.array_equal(r1.q_rounds, exact.q_rounds)

    # legacy wrapper: cached engine, no rebuild/retrace across calls, and
    # out-of-range sources now rejected on the shmap path too
    d, st = solve_shmap_batch(sh, [3, 17, 99], SsspConfig(), mesh, ("d",))
    weng = engine_for(sh, SsspConfig(), "shmap", mesh, ("d",))
    t0 = dict(weng.trace_counts)
    d2, _ = solve_shmap_batch(sh, [8, 9, 10], SsspConfig(), mesh, ("d",))
    assert weng.trace_counts == t0 == {4: 1}, weng.trace_counts
    assert np.array_equal(d, r1.dist)
    try:
        solve_shmap_batch(sh, [g.n_vertices + 5], SsspConfig(), mesh, ("d",))
        raise SystemExit("out-of-range source accepted on shmap")
    except ValueError:
        pass
    print("SHMAP ENGINE OK")
""")


def test_engine_shmap_trace_reuse_and_validation():
    """shmap: one compiled whole-solve program per K-bucket serves
    arbitrary source sets (the old path recompiled per batch); wrapper
    calls reuse the cached engine; sources validated like sim
    (subprocess: device count must be set before jax initializes)."""
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", _SHMAP_ENGINE_PROG], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "SHMAP ENGINE OK" in out.stdout
