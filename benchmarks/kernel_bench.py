"""Kernel micro-benchmarks: Pallas (interpret) vs jnp fallback vs oracle.

On this CPU container interpret-mode timings are NOT TPU perf — the
numbers recorded are correctness + working-set documentation; TPU-side
perf is covered analytically in EXPERIMENTS.md §Roofline.
"""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.graph import random_graph
from repro.graph.structure import graph_to_numpy
from repro.kernels.relax import (build_dst_tiled_layout, relax_fixpoint_pallas,
                                 relax_jnp, relax_masked_pallas, relax_pallas)
from repro.kernels.flash_attention import flash_attention, attention_ref
from repro.kernels.embedding_bag import embedding_bag, embedding_bag_jnp

rng = np.random.default_rng(0)


def _timeit(f, *a, repeats=5):
    out = f(*a)
    jax.block_until_ready(out)
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(f(*a))
        ts.append(time.perf_counter() - t0)
    return min(ts) * 1e6


def bench_relax(out):
    g = random_graph(2000, 16000, seed=1)
    src, dst, w = graph_to_numpy(g)
    n = g.n_vertices
    dist = rng.uniform(0, 50, n).astype(np.float32)
    src_t, w_t, dr_t, eid_t, bp = build_dst_tiled_layout(src, dst, w, n,
                                                         with_eid=True)
    dist_pad = jnp.asarray(np.concatenate([dist, np.full(bp - n, np.inf,
                                                         np.float32)]))
    t_j = _timeit(relax_jnp, jnp.asarray(dist), jnp.asarray(src),
                  jnp.asarray(dst), jnp.asarray(w))
    out("relax_xla[2k_v,16k_e]", t_j, "scatter-min lowering")
    t_p = _timeit(lambda d: relax_pallas(d, src_t, w_t, dr_t), dist_pad)
    out("relax_pallas_interp[2k_v,16k_e]", t_p,
        "dst-tiled one-hot min (interpret mode)")
    # solver-contract variants: frontier mask + pruned mask + relax count
    front_pad = jnp.asarray(np.concatenate(
        [np.ones(n, np.float32), np.zeros(bp - n, np.float32)]))
    pruned_t = jnp.zeros(src_t.shape, jnp.int32)
    t_m = _timeit(lambda d: relax_masked_pallas(d, front_pad, src_t, w_t,
                                                dr_t, pruned_t), dist_pad)
    out("relax_pallas_masked_interp[2k_v,16k_e]", t_m,
        "+frontier/pruned/count (solver contract)")
    t_f = _timeit(lambda d: relax_fixpoint_pallas(d, front_pad, src_t, w_t,
                                                  dr_t, pruned_t, n_sweeps=8),
                  dist_pad)
    out("relax_pallas_fixpoint8_interp[2k_v,16k_e]", t_f,
        "8 fused sweeps/one pallas_call (early-out)")


def bench_flash(out):
    B, H, S, D = 1, 4, 512, 64
    q = jnp.asarray(rng.standard_normal((B, H, S, D)), jnp.float32)
    k, v = q, q
    t_ref = _timeit(lambda: attention_ref(q, k, v))
    out(f"attention_ref[B{B}H{H}S{S}]", t_ref, "materialized scores")
    t_p = _timeit(lambda: flash_attention(q, k, v))
    out(f"flash_pallas_interp[B{B}H{H}S{S}]", t_p, "interpret mode")


def bench_embag(out):
    V, Dm, B, L = 50_000, 32, 1024, 4
    table = jnp.asarray(rng.standard_normal((V, Dm)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, V, (B, L)), jnp.int32)
    t_j = _timeit(embedding_bag_jnp, table, idx)
    out(f"embag_xla[V{V}B{B}L{L}]", t_j, "take+masked-sum")
    t_p = _timeit(lambda: embedding_bag(table, idx, bb=8))
    out(f"embag_pallas_interp[V{V}B{B}L{L}]", t_p, "row-DMA gather")


def run_all(out):
    bench_relax(out)
    bench_flash(out)
    bench_embag(out)
