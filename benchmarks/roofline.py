"""Aggregate dry-run artifacts into the roofline table (EXPERIMENTS.md §Roofline).

Reads benchmarks/artifacts/dryrun/*.json produced by repro.launch.dryrun.
"""
from __future__ import annotations

import glob
import json
import os

ART = os.path.join(os.path.dirname(__file__), "artifacts", "dryrun")


def load_records(pattern="*.json"):
    recs = []
    for f in sorted(glob.glob(os.path.join(ART, pattern))):
        with open(f) as fh:
            recs.append(json.load(fh))
    return recs


def _fmt_s(x):
    if x is None:
        return "-"
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.1f}ms"
    return f"{x * 1e6:.0f}us"


def table(multi_pod=False, out=print):
    """Single-pod: full roofline terms. Multi-pod: compile/memory proof only
    (its LM cells skip the unrolled FLOPs pass, so compute/useful would be
    misleading there — the roofline table is single-pod by design)."""
    rows = []
    for r in load_records():
        if r.get("multi_pod") != multi_pod:
            continue
        tag = f"{r['arch']} x {r['shape']}"
        if r["status"] == "skipped":
            rows.append((tag, "SKIP", "-", "-", "-", "-", "-", "-"))
            continue
        if r["status"] == "error":
            rows.append((tag, "ERROR", "-", "-", "-", "-", "-", "-"))
            continue
        t = r["roofline"]
        mem = r["memory"]["temp_bytes"]
        if multi_pod:
            rows.append((tag, "ok", "-", "-", _fmt_s(t["collective_s"]),
                         "-", "-", f"{(mem or 0) / 2**30:.1f}G"))
        else:
            rows.append((
                tag, t["dominant"],
                _fmt_s(t["compute_s"]), _fmt_s(t["memory_s"]),
                _fmt_s(t["collective_s"]),
                f"{t['useful_ratio']:.2f}",
                f"{t['roofline_fraction']:.2f}",
                f"{(mem or 0) / 2**30:.1f}G",
            ))
    hdr = ("cell", "status" if multi_pod else "dominant", "compute",
           "memory", "collective", "useful", "roofline-frac", "temp/dev")
    w = [max(len(str(r[i])) for r in rows + [hdr]) for i in range(len(hdr))]
    out("  ".join(h.ljust(w[i]) for i, h in enumerate(hdr)))
    for r in rows:
        out("  ".join(str(c).ljust(w[i]) for i, c in enumerate(r)))
    return rows


def bench_roofline(out):
    """Benchmark-harness entry: emit one line per dry-run cell."""
    for r in load_records():
        if r["status"] != "ok":
            continue
        mesh = "multipod" if r["multi_pod"] else "singlepod"
        t = r["roofline"]
        out(f"roofline[{r['arch']}x{r['shape']}@{mesh}]",
            t["bound_s"] * 1e6,
            f"dom={t['dominant']} useful={t['useful_ratio']:.2f}")


if __name__ == "__main__":
    print("=== single-pod (16x16) ===")
    table(False)
    print()
    print("=== multi-pod (2x16x16) ===")
    table(True)
