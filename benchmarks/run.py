"""Benchmark harness — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Sections:
  - sssp_runtime / speedup / MTEPS  (paper Figs 1-2)
  - trishla                          (paper's pruning contribution)
  - toka                             (termination-detection comparison)
  - local_solver                     (intra-node Dijkstra-order ablation)
  - kernels                          (Pallas vs XLA micro)
  - roofline                         (dry-run derived terms, if artifacts exist)
"""
from __future__ import annotations

import sys


def _out(name, us, derived=""):
    print(f"{name},{us:.1f},{derived}")


def main() -> None:
    only = sys.argv[1] if len(sys.argv) > 1 else None

    from benchmarks import sssp_bench, kernel_bench
    if only in (None, "sssp"):
        sssp_bench.run_all(_out)
        from benchmarks import sssp_perf_study
        sssp_perf_study.run(out=lambda s: print(f"# {s}"))
    if only in (None, "kernels"):
        kernel_bench.run_all(_out)
    if only in (None, "roofline"):
        try:
            from benchmarks import roofline
            roofline.bench_roofline(_out)
        except Exception as e:  # artifacts may not exist yet
            print(f"# roofline skipped: {e}")


if __name__ == "__main__":
    main()
