"""Benchmark harness — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV and writes ``BENCH_sssp.json``
(machine-readable: per-benchmark name, wall time, MTEPS where reported) so
the perf trajectory is tracked across PRs. Sections:
  - sssp_runtime / speedup / MTEPS  (paper Figs 1-2)
  - trishla                          (paper's pruning contribution)
  - toka                             (termination-detection comparison)
  - local_solver                     (intra-node Dijkstra-order ablation,
                                      incl. the Pallas dst-tiled kernel path)
  - kernels                          (Pallas vs XLA micro)
  - roofline                         (dry-run derived terms, if artifacts exist)
"""
from __future__ import annotations

import json
import os
import re
import sys

_RECORDS: list[dict] = []
_MTEPS_RE = re.compile(r"mteps=([0-9.]+)")
_QPS_RE = re.compile(r"qps=([0-9.]+)")


def _out(name, us, derived=""):
    print(f"{name},{us:.1f},{derived}")
    rec = {"name": name, "us": round(float(us), 1), "derived": derived}
    m = _MTEPS_RE.search(derived)
    if m:
        rec["mteps"] = float(m.group(1))
    m = _QPS_RE.search(derived)
    if m:
        rec["qps"] = float(m.group(1))
    _RECORDS.append(rec)


def _write_json(path="BENCH_sssp.json"):
    # repo root (next to benchmarks/), wherever the harness is launched from
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    full = os.path.join(root, path)
    # merge with the existing file so a partial-section run (`run.py
    # kernels`) refreshes its own records without clobbering the rest of
    # the tracked perf trajectory
    merged = {}
    if os.path.exists(full):
        try:
            with open(full) as f:
                merged = {r["name"]: r for r in json.load(f)["benchmarks"]}
        except (json.JSONDecodeError, KeyError):
            merged = {}
    merged.update((r["name"], r) for r in _RECORDS)
    with open(full, "w") as f:
        json.dump({"benchmarks": list(merged.values())}, f, indent=1)
    print(f"# wrote {path} ({len(_RECORDS)} new, {len(merged)} total records)")


def main() -> None:
    only = sys.argv[1] if len(sys.argv) > 1 else None

    from benchmarks import sssp_bench, kernel_bench
    if only in (None, "sssp"):
        sssp_bench.run_all(_out)
        from benchmarks import sssp_perf_study
        sssp_perf_study.run(out=lambda s: print(f"# {s}"))
    if only in (None, "kernels"):
        kernel_bench.run_all(_out)
    if only in (None, "roofline"):
        try:
            from benchmarks import roofline
            roofline.bench_roofline(_out)
        except Exception as e:  # artifacts may not exist yet
            print(f"# roofline skipped: {e}")
    _write_json()


if __name__ == "__main__":
    main()
