"""§Perf hillclimb for the paper's technique (SP-Async itself).

Ladder of configurations from paper-faithful baseline to beyond-paper:
  A  pmin exchange (dense inter-node Bellman-Ford broadcast), blind local
     sweeps, toka2 token ring  — the paper's algorithm, literal port
  B  + Dijkstra-order local settling (delta)                — paper's intent
  C  + Trishla offline pruning                               — paper's Trishla
  D  + bucketed pre-aggregated exchange (one msg per boundary
       vertex, improvements only)                            — beyond paper
       (the paper's future-work "message buffering" made static)
  E  + toka0 quiescence detection (BSP all-reduce)           — beyond paper

Measured on CPU (solve_sim) over road-like and social-like graphs;
message counts are transport-independent, wall times are CPU-relative.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import SsspConfig, build_shards, solve_sim
from repro.graph import rmat_graph, road_grid_graph, dijkstra_reference

LADDER = [
    ("A_paper_baseline", SsspConfig(exchange="pmin", local_solver="bellman",
                                    toka="toka2", prune_online=False)),
    ("B_+delta", SsspConfig(exchange="pmin", local_solver="delta", delta=6.0,
                            toka="toka2", prune_online=False)),
    ("C_+trishla", SsspConfig(exchange="pmin", local_solver="delta", delta=6.0,
                              toka="toka2", prune_offline_passes=1,
                              prune_online=True)),
    ("D_+bucket", SsspConfig(exchange="bucket", local_solver="delta",
                             delta=6.0, toka="toka2", prune_offline_passes=1,
                             prune_online=True)),
    ("E_+toka0", SsspConfig(exchange="bucket", local_solver="delta", delta=6.0,
                            toka="toka0", prune_offline_passes=1,
                            prune_online=True)),
]

GRAPHS = {
    "road(graph2-like)": lambda: road_grid_graph(side=40, seed=2),
    "social(graph3-like)": lambda: rmat_graph(scale=9, edge_factor=16, seed=3),
}


def run(out=print):
    for gname, build in GRAPHS.items():
        g = build()
        source = int(g.src[0])
        ref = dijkstra_reference(g, source)
        sh = build_shards(g, 8)
        out(f"# {gname}: {g.n_vertices}v {g.n_edges}e, P=8")
        for name, cfg in LADDER:
            dist, stats = solve_sim(sh, source, cfg)   # compile warmup
            t0 = time.perf_counter()
            dist, stats = solve_sim(sh, source, cfg)
            dt = time.perf_counter() - t0
            ok = np.allclose(dist, ref, 1e-5, 1e-4)
            out(f"{name:18s} t={dt*1e3:7.1f}ms rounds={int(stats.rounds):4d} "
                f"relax={int(stats.relaxations):8d} msgs={int(stats.msgs_sent):7d} "
                f"pruned={int(stats.pruned_edges):6d} ok={ok}")


if __name__ == "__main__":
    run()
