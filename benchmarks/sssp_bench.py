"""Paper §IV benchmarks (Figs 1-3 analogs), executed on CPU at reduced scale.

- runtime vs number of partitions (Fig 1) and speedup (Fig 2)
- MTEPS (million traversed edges per second)
- Trishla effectiveness: edges pruned, relaxations saved
- ToKa comparison: rounds + message overhead of toka0/1/2

Graphs are generated analogs of the paper's four (ParMat/R-MAT synthetic,
road grid) scaled to CPU: the paper's *shape* (vertex/edge ratio) is kept.
"""
from __future__ import annotations

import re
import time

import jax
import numpy as np

from repro.core import (FaultPlan, SsspConfig, SsspEngine, build_shards,
                        build_shards_stream, engine_for, sim_phase_fns,
                        solve_sim, solve_sim_batch)
from repro.core import sssp as sssp_mod
from repro.graph import (dijkstra_reference, preset_edge_stream, rmat_graph,
                         road_grid_graph)

BENCH_GRAPHS = {
    # name: builder — e/v ratios mimic graph1 (2.2), graph2 road (2.4, grid),
    # graph3 social (38)
    "graph1-like": lambda: rmat_graph(scale=11, edge_factor=2, seed=1),
    "graph2-like": lambda: road_grid_graph(side=48, seed=2),
    "graph3-like": lambda: rmat_graph(scale=9, edge_factor=24, seed=3),
}


def _solve_timed(sh, source, cfg, repeats=3):
    # warmup + compile
    dist, stats = solve_sim(sh, source, cfg)
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        dist, stats = solve_sim(sh, source, cfg)
        ts.append(time.perf_counter() - t0)
    return dist, stats, min(ts)


def bench_scaling(out):
    """Fig 1/2: runtime + speedup vs partitions."""
    for name, build in BENCH_GRAPHS.items():
        g = build()
        source = int(g.src[0])
        base_t = None
        for p in (1, 2, 4, 8, 16):
            sh = build_shards(g, p, enumerate_triangles=False)
            cfg = SsspConfig(prune_online=False)
            dist, stats, t = _solve_timed(sh, source, cfg)
            base_t = base_t or t
            mteps = int(stats.relaxations) / t / 1e6
            out(f"sssp_runtime[{name}][P={p}]", t * 1e6,
                f"speedup={base_t / t:.2f} mteps={mteps:.1f} "
                f"rounds={int(stats.rounds)}")


def bench_trishla(out):
    """Trishla: pruned edges + relaxation savings (paper's TEPS argument)."""
    for name, build in BENCH_GRAPHS.items():
        g = build()
        source = int(g.src[0])
        sh = build_shards(g, 8)
        _, s0, t0 = _solve_timed(sh, source, SsspConfig(prune_online=False))
        _, s1, t1 = _solve_timed(sh, source,
                                 SsspConfig(prune_offline_passes=1,
                                            prune_online=False))
        saved = 1 - int(s1.relaxations) / max(int(s0.relaxations), 1)
        out(f"trishla[{name}]", t1 * 1e6,
            f"pruned={int(s1.pruned_edges)}/{g.n_edges} "
            f"relax_saved={saved:.1%}")


def bench_toka(out):
    """Termination detection overhead: rounds + wall time per detector."""
    g = BENCH_GRAPHS["graph1-like"]()
    source = int(g.src[0])
    sh = build_shards(g, 8, enumerate_triangles=False)
    ref = dijkstra_reference(g, source)
    for toka in ("toka0", "toka1", "toka2"):
        cfg = SsspConfig(toka=toka, prune_online=False)
        dist, stats, t = _solve_timed(sh, source, cfg)
        ok = np.allclose(dist, ref, 1e-5, 1e-4)
        out(f"toka[{toka}]", t * 1e6,
            f"rounds={int(stats.rounds)} msgs={int(stats.msgs_sent)} ok={ok}")


def bench_local_solver(out):
    """Dijkstra-order (delta) vs blind sweeps: relaxation efficiency."""
    g = BENCH_GRAPHS["graph2-like"]()
    source = int(g.src[0])
    sh = build_shards(g, 8, enumerate_triangles=False)
    for solver, delta in (("bellman", 0.0), ("delta", 4.0), ("delta", 12.0)):
        cfg = SsspConfig(local_solver=solver, delta=delta, prune_online=False)
        _, stats, t = _solve_timed(sh, source, cfg)
        out(f"local_solver[{solver}-{delta}]", t * 1e6,
            f"relax={int(stats.relaxations)} rounds={int(stats.rounds)}")


def bench_pallas_solver(out):
    """End-to-end pallas vs bellman vs delta on every bench graph.

    The dst-tiled layout rides in the shards (built once at partition
    time); interpret-mode wall times are NOT TPU perf — MTEPS here tracks
    the CPU-emulated trajectory so regressions in the kernel path are
    visible from this PR onward."""
    for name, build in BENCH_GRAPHS.items():
        g = build()
        source = int(g.src[0])
        sh = build_shards(g, 8, enumerate_triangles=False)
        ref = dijkstra_reference(g, source)
        for solver in ("bellman", "delta", "pallas"):
            cfg = SsspConfig(local_solver=solver, prune_online=False)
            dist, stats, t = _solve_timed(sh, source, cfg)
            ok = np.allclose(dist, ref, 1e-5, 1e-4)
            mteps = int(stats.relaxations) / t / 1e6
            out(f"local_solver[{solver}][{name}]", t * 1e6,
                f"mteps={mteps:.4f} relax={int(stats.relaxations)} "
                f"rounds={int(stats.rounds)} ok={ok}")


def bench_batch_throughput(out):
    """Query-engine throughput: queries/sec and aggregate MTEPS vs batch
    size K.

    One ``build_shards``, many sources: the compiled round, the per-round
    collectives, and (for pallas) the dst-tiled edge layout are shared by
    the whole batch, so the per-query cost of a round is amortized — the
    per-source launch/dispatch overhead that dominates single-source runs
    (the batching argument of the MPI+CUDA Dijkstra study) is paid once
    per K queries."""
    for name, build in BENCH_GRAPHS.items():
        g = build()
        rng = np.random.default_rng(9)
        sh = build_shards(g, 8, enumerate_triangles=False)
        cfg = SsspConfig(prune_online=False)
        for k in (1, 4, 16):
            sources = sorted(int(s) for s in
                             rng.choice(g.n_vertices, size=k, replace=False))
            solve_sim_batch(sh, sources, cfg)      # warmup + compile
            ts = []
            for _ in range(3):
                t0 = time.perf_counter()
                _, stats = solve_sim_batch(sh, sources, cfg)
                ts.append(time.perf_counter() - t0)
            t = min(ts)
            mteps = int(stats.relaxations) / t / 1e6
            out(f"batch_throughput[{name}][K={k}]", t * 1e6,
                f"qps={k / t:.3f} mteps={mteps:.4f} "
                f"rounds={int(stats.rounds)}")


def bench_engine_serving(out):
    """Serving economics of the session engine: cold compile vs warm query
    latency, plus sustained queries/s over a streamed arrival trace.

    ``SsspEngine`` keeps sources TRACED, so one compiled program per
    K-bucket answers arbitrary source sets — the cold/warm gap here IS the
    compile amortization the engine exists for, and ``recompiles`` in the
    warm records must stay 0 (asserted by the trace counter, not inferred
    from timing). The stream section replays a ragged arrival trace
    (single queries mixed with small bursts) through submit/drain so the
    bucket coalescing policy is what's measured."""
    g = BENCH_GRAPHS["graph1-like"]()
    rng = np.random.default_rng(13)
    sh = build_shards(g, 8, enumerate_triangles=False)
    eng = SsspEngine.build(sh, SsspConfig(prune_online=False), max_bucket=16)
    for k in (1, 4, 16):
        sources = [int(s) for s in
                   rng.choice(g.n_vertices, size=k, replace=False)]
        cold = eng.solve(sources)
        out(f"engine_serving[cold][K={k}]", cold.wall_s * 1e6,
            f"compile_s={cold.compile_s:.3f} bucket={cold.bucket_k}")
        warm_ts, recompiles = [], 0
        for _ in range(3):
            res = eng.solve([int(s) for s in
                             rng.choice(g.n_vertices, size=k, replace=False)])
            warm_ts.append(res.wall_s)
            recompiles += int(res.compiled)
        t = min(warm_ts)
        out(f"engine_serving[warm][K={k}]", t * 1e6,
            f"qps={k / t:.3f} recompiles={recompiles} "
            f"amortization={cold.wall_s / t:.1f}x")
        assert recompiles == 0, "warm engine.solve must not recompile"
    # streamed arrival trace: 24 arrivals, ragged sizes 1/2/4, coalesced
    # into max_bucket batches by drain()
    trace0, batches0 = eng.trace_count, eng.batches_served
    handles = []
    t0 = time.perf_counter()
    for size in rng.choice([1, 1, 2, 4], size=24):
        handles.append(eng.submit([int(s) for s in
                                   rng.choice(g.n_vertices, size=int(size),
                                              replace=False)]))
    eng.drain()
    t = time.perf_counter() - t0
    nq = sum(len(h.sources) for h in handles)
    out(f"engine_serving[stream][{nq}q]", t * 1e6,
        f"qps={nq / t:.3f} batches={eng.batches_served - batches0} "
        f"recompiles={eng.trace_count - trace0}")


def bench_warm_start(out):
    """Warm-start economics: cold rounds/qps vs landmark-seeded rounds/qps
    vs result-cache hits (the `warm_start` section of BENCH_sssp.json).

    Three tiers of the cache hierarchy on the same shards:
      - cold: the baseline full-wave solve
      - landmark: repeated sources seeded from the landmark cache — the
        seed IS the pivot's solved fixpoint, so quiescence is confirmed in
        ~1 round instead of re-propagating the wave (bit-identical dist,
        asserted)
      - cache_hit: exact repeats served from the result LRU with ZERO
        rounds and no compiled program at all
    Warm paths must not recompile: the second warm solve's `compiled` flag
    is asserted False (same trace-counter discipline as engine_serving)."""
    for name in ("graph1-like", "graph2-like"):
        g = BENCH_GRAPHS[name]()
        rng = np.random.default_rng(23)
        sh = build_shards(g, 8, enumerate_triangles=False)
        # pivot from vertices WITH out-edges: an isolated source solves in
        # one round cold, leaving no rounds for the warm path to save
        candidates = np.unique(np.asarray(g.src))
        pivots = sorted(int(s) for s in
                        rng.choice(candidates, size=4, replace=False))
        cold_eng = SsspEngine.build(sh, SsspConfig(prune_online=False))
        warm_eng = SsspEngine.build(
            sh, SsspConfig(prune_online=False, warm_start="landmark"),
            result_cache=32)
        warm_eng.precompute_landmarks(pivots)
        for k in (1, 4):
            sources = pivots[:k]
            cold_eng.solve(sources)                       # warmup + compile
            ts = []
            for _ in range(3):
                t0 = time.perf_counter()
                cold = cold_eng.solve(sources)
                ts.append(time.perf_counter() - t0)
            t_cold = min(ts)
            out(f"warm_start[{name}][cold][K={k}]", t_cold * 1e6,
                f"qps={k / t_cold:.3f} rounds={int(cold.stats.rounds)}")
            # landmark-seeded repeats (bypass the LRU: seed-path rounds)
            warm_eng._solve_batch(tuple(sources))         # warmup + compile
            ts, recompiles = [], 0
            for _ in range(3):
                t0 = time.perf_counter()
                warm = warm_eng._solve_batch(tuple(sources))
                ts.append(time.perf_counter() - t0)
                recompiles += int(warm.compiled)
            t_warm = min(ts)
            assert recompiles == 0, "warm landmark solves must not recompile"
            assert np.array_equal(cold.dist, warm.dist), \
                "warm-started solve must be bit-identical to cold"
            assert int(warm.stats.rounds) <= int(cold.stats.rounds)
            if int(cold.stats.rounds) > 2:
                # graphs with real round depth (the road grid always; the
                # rmat graphs at full scale) must show a STRICT decrease
                assert int(warm.stats.rounds) < int(cold.stats.rounds), \
                    "landmark seeding must cut rounds on repeated sources"
            out(f"warm_start[{name}][landmark][K={k}]", t_warm * 1e6,
                f"qps={k / t_warm:.3f} rounds={int(warm.stats.rounds)} "
                f"cold_rounds={int(cold.stats.rounds)} "
                f"speedup={t_cold / t_warm:.1f}x")
        # exact repeats: the result LRU answers without any solve
        warm_eng.solve(pivots)
        ts = []
        for _ in range(3):
            t0 = time.perf_counter()
            hit = warm_eng.solve(pivots)
            ts.append(time.perf_counter() - t0)
        t_hit = min(ts)
        assert hit.cache_hits == len(pivots) and int(hit.stats.rounds) == 0
        out(f"warm_start[{name}][cache_hit][K={len(pivots)}]", t_hit * 1e6,
            f"qps={len(pivots) / t_hit:.3f} rounds=0 "
            f"hits={hit.cache_hits}")


def bench_faults(out):
    """Resilience economics: rounds-to-converge and resend overhead vs
    drop rate, under anti-entropy healing with the toka3 timeout detector.

    Every faulted record carries TWO hard asserts — distances bit-identical
    to the fault-free solve and ``status == "converged"`` (the engine's
    fixpoint certificate, not the detector's word) — so this section is a
    correctness gate for the whole fault/recovery/termination stack, not
    just a perf artifact. ``resend_overhead`` is the fraction of all sent
    messages that were anti-entropy retransmissions: the price of healing
    at that drop rate."""
    g = BENCH_GRAPHS["graph2-like"]()    # road grid: real round depth
    source = int(g.src[0])
    sh = build_shards(g, 8, enumerate_triangles=False)
    base_eng = SsspEngine.build(sh, SsspConfig(prune_online=False))
    base = base_eng.solve(source)
    out(f"faults[drop=0.0][toka0]", base.wall_s * 1e6,
        f"rounds={int(base.stats.rounds)} resends=0 overhead=0.00 "
        f"status={base.status}")
    for drop in (0.1, 0.3):
        for toka in ("toka0", "toka3"):
            cfg = SsspConfig(prune_online=False, toka=toka,
                             faults=FaultPlan(drop=drop, seed=5,
                                              resend_period=4))
            eng = SsspEngine.build(sh, cfg)
            res = eng.solve(source)
            assert np.array_equal(res.dist, base.dist), \
                f"faulted solve (drop={drop}, {toka}) lost bit-identity"
            assert res.status == "converged", \
                f"faulted solve (drop={drop}, {toka}) did not certify"
            overhead = int(res.stats.resends) / max(int(res.stats.msgs_sent),
                                                    1)
            out(f"faults[drop={drop}][{toka}]", res.wall_s * 1e6,
                f"rounds={int(res.stats.rounds)} "
                f"base_rounds={int(base.stats.rounds)} "
                f"resends={int(res.stats.resends)} "
                f"overhead={overhead:.2f} status={res.status}")


def bench_async_scaling(out):
    """Sync vs deferred exchange across partition counts: the paper's
    asynchronous-mode claim (Fig 1/2 analog) as measured round/traffic
    numbers plus a clearly-labeled MODELED speedup.

    Measured per (graph, P): rounds, sim wall time, stale merges, overlap
    fraction, and wire bytes for the synchronous ``bucket`` baseline, the
    double-buffered ``async`` exchange, and the ring-streaming
    ``async_ppermute`` (all at P >= 2 — at P=1 a deferred exchange is
    degenerate: nothing ever rides the wire) — every async solve
    hard-asserted bit-identical to sync. The sim cannot time real overlap
    (its lock-step emulation serializes on one CPU, and its wall time is
    per-round dispatch overhead, not transport), so ``modeled_speedup``
    prices each run's MEASURED structure — rounds, per-round relaxations,
    per-round wire bytes, overlap fraction — with an alpha-beta transport
    model at accelerator constants:

      C        = (relaxations / rounds / P) / R        per-shard compute
      sync rnd = C_s + alpha*(1 + log2 P) + beta*B     (tree barrier)
      async rnd= of*max(C_a, h) + (1 - of)*(C_a + h),
                 h = alpha + beta*B                    (neighbor hop)

    alpha=5us (collective dispatch latency), beta=0.1ns/B, R=10M
    relaxations/s (the interpret-mode kernels' own order of magnitude;
    on the megakernel's accounting, round time at these graph scales IS
    the per-round latency, which is exactly what deferring the collective
    removes). The async speedup must be monotone non-decreasing in P on
    at least one bench graph (hard assert): more partitions means more
    barrier latency for sync to pay and less per-shard compute to pay it
    behind, which is the whole argument for the asynchronous mode."""
    ALPHA, BETA, R = 5e-6, 1e-10, 1e7
    monotone = []
    for name, build in BENCH_GRAPHS.items():
        g = build()
        source = int(g.src[0])
        speedups = []
        for p in (2, 4, 8):
            sh = build_shards(g, p, enumerate_triangles=False)
            base, s_sync, t_sync = _solve_timed(
                sh, source, SsspConfig(prune_online=False))
            r_sync = int(s_sync.rounds)
            c_sync = int(s_sync.relaxations) / r_sync / p / R
            t_sync_model = r_sync * (c_sync + ALPHA * (1 + np.log2(p)))
            for ex in ("async", "async_ppermute"):
                cfg = SsspConfig(prune_online=False, exchange=ex)
                dist, s, t = _solve_timed(sh, source, cfg)
                assert np.array_equal(np.asarray(dist), np.asarray(base)), \
                    (name, p, ex, "async exchange lost bit-identity")
                r = int(s.rounds)
                of = int(s.overlap_rounds) / r
                bpr = int(s.bytes_moved) / r
                c_async = int(s.relaxations) / r / p / R
                hop = ALPHA + BETA * bpr
                t_async_model = r * (of * max(c_async, hop)
                                     + (1 - of) * (c_async + hop))
                speedup = (t_sync_model + BETA * bpr * r_sync) \
                    / t_async_model
                if ex == "async":
                    speedups.append(speedup)
                out(f"async_scaling[{name}][{ex}][P={p}]", t * 1e6,
                    f"modeled_speedup={speedup:.2f} overlap={of:.2f} "
                    f"rounds={r} extra_rounds={r - r_sync} "
                    f"stale={int(np.asarray(s.stale_merges).sum())} "
                    f"bytes={int(s.bytes_moved)} "
                    f"sync_wall_us={t_sync * 1e6:.0f}")
        monotone.append(all(b >= a - 1e-9
                            for a, b in zip(speedups, speedups[1:])))
    assert any(monotone), (
        "modeled async speedup must be monotone non-decreasing in P on at "
        "least one bench graph")


def _block(x):
    return jax.tree_util.tree_map(
        lambda a: a.block_until_ready() if hasattr(a, "block_until_ready")
        else a, x)


def _time_fn(fn, *args, repeats=5):
    _block(fn(*args))                      # warmup + compile
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        _block(fn(*args))
        ts.append(time.perf_counter() - t0)
    return min(ts)


def _pallas_grids(fn, *args):
    """All pallas_call grids inside ``fn``'s jaxpr (recursing through
    subjaxprs). The grid is the kernel's TILE-LOAD schedule: its product
    is how many layout tiles one dispatch streams from HBM, which is the
    cost that matters on a real accelerator (interpret-mode wall time on
    CPU executes every vector lane and cannot see it)."""
    found = []

    def walk(jaxpr):
        for eqn in jaxpr.eqns:
            if eqn.primitive.name == "pallas_call":
                found.append(tuple(eqn.params["grid_mapping"].grid))
            for v in eqn.params.values():
                if isinstance(v, jax.core.ClosedJaxpr):
                    walk(v.jaxpr)
                elif isinstance(v, jax.core.Jaxpr):
                    walk(v)

    walk(jax.make_jaxpr(fn)(*args).jaxpr)
    return found


def _grid_steps(grids):
    return sum(int(np.prod(g)) for g in grids) if grids else 0


def bench_phase_breakdown(out):
    """Per-phase wall time of one round (local / send / exchange / merge)
    on real mid-solve state, for both send/merge backend pairs and
    K in {1, 16} — so a kernel win (or regression) is attributable to the
    phase that caused it, not smeared over the whole solve — plus the
    fused megakernel (``round='fused'``) against the best staged
    data-plane total at each K.

    Methodology: run two full rounds from the initial carry to reach a
    state with live frontiers on every shard, then drive each phase of
    round three in isolation through ``sim_phase_fns`` (the same stage
    callables the round dispatches) with jitted, block_until_ready timing.
    Interpret-mode pallas times are NOT TPU perf (same caveat as the relax
    kernel benchmarks): interpret mode executes every vector lane on CPU,
    so a [K]-in-register kernel still pays K x the compute and wall time
    cannot distinguish 'loads each tile once' from 'K x lanes of math'.
    The accelerator cost model lives in the GRID instead, so each pallas
    row records ``grid_steps`` (grid product: sequential kernel steps per
    dispatch; for the send/merge/fused kernels, whose [K] axis is
    in-register, this equals layout tiles streamed — the relax kernel
    keeps q in-grid but innermost with q-invariant edge index maps, so
    its edge tiles still load once per (vtile, chunk)). The regression
    guards are HARD asserts on the grids: the 85 ms cliff this replaced
    came from send/merge grids of ``(tiles, chunks, K)`` (tile loads x16
    at K=16); the batched kernels must keep the grid K-INDEPENDENT
    (identical at K=1 and K=16, i.e. well within the 2x bound the issue
    set, vs the 16x of the cliff)."""
    g = BENCH_GRAPHS["graph1-like"]()
    rng = np.random.default_rng(11)
    sh = build_shards(g, 8, enumerate_triangles=False)
    grids_by_k = {}
    for k in (1, 16):
        sources = sorted(int(s) for s in
                         rng.choice(g.n_vertices, size=k, replace=False))
        staged = {}
        staged_loads = {}
        for backend in ("xla", "pallas"):
            # all-XLA vs all-pallas: the pallas column must include the
            # relax kernel too, or its grid_steps undercount the staged
            # round and the fused comparison is unfairly flattering
            cfg = SsspConfig(prune_online=False, send_backend=backend,
                             merge_backend=backend,
                             local_solver="pallas" if backend == "pallas"
                             else "bellman")
            dpr = sssp_mod.dispatches_per_round(sh, cfg)
            round_fn = engine_for(sh, cfg).round_fn
            carry = sssp_mod._init_carry(sh, sources, cfg, rank=None,
                                         vmapped=True)
            carry = round_fn(round_fn(carry))      # mid-solve state
            fns = sim_phase_fns(sh, cfg)
            act = carry.active & ~carry.done[..., None]
            dist = fns["local"](carry.dist, act, carry.pruned,
                                carry.tri_cursor)[0]
            payload = fns["send"](dist, carry.pruned, carry.last_sent)[0]
            incoming = fns["exchange"](payload)
            phase_args = {
                "local": (fns["local"], carry.dist, act, carry.pruned,
                          carry.tri_cursor),
                "send": (fns["send"], dist, carry.pruned, carry.last_sent),
                "exchange": (fns["exchange"], payload),
                "merge": (fns["merge"], dist, incoming),
            }
            times = {ph: _time_fn(*fa) for ph, fa in phase_args.items()}
            grids = {ph: _pallas_grids(*fa) for ph, fa in phase_args.items()}
            staged[backend] = times
            staged_loads[backend] = sum(_grid_steps(gs)
                                        for gs in grids.values())
            total = sum(times.values())
            for phase, t in times.items():
                out(f"phase[{phase}][K={k}][{backend}]", t * 1e6,
                    f"share={t / total:.2f} dispatches_per_round={dpr} "
                    f"grid_steps={_grid_steps(grids[phase])}")
            if backend == "pallas":
                grids_by_k.setdefault(k, {}).update(
                    {ph: grids[ph] for ph in ("send", "merge")})
        # fused megakernel: ONE dispatch replaces local+send+merge; its
        # fair staged comparison is the best data-plane total (same work,
        # exchange excluded from both sides). Wall time in interpret mode
        # still pays K x lanes + per-grid-step Python overhead; the fusion
        # win is the dispatch count (2 vs 4) and the single shared tile
        # stream, both recorded in the derived fields.
        fcfg = SsspConfig(prune_online=False, round="fused")
        fdpr = sssp_mod.dispatches_per_round(sh, fcfg)
        fround = engine_for(sh, fcfg).round_fn
        fcarry = fround(fround(sssp_mod._init_carry(sh, sources, fcfg,
                                                    rank=None, vmapped=True)))
        ffns = sim_phase_fns(sh, fcfg)
        live = ~fcarry.done
        front_in = fcarry.active & live[..., None]
        fargs = (ffns["fused"], fcarry.dist, front_in, live, fcarry.incoming,
                 fcarry.last_sent, fcarry.pruned)
        t_fused = _time_fn(*fargs)
        fgrids = _pallas_grids(*fargs)
        grids_by_k[k]["fused"] = fgrids
        best_staged = min(
            sum(t for ph, t in times.items() if ph != "exchange")
            for times in staged.values())
        out(f"phase[fused][K={k}]", t_fused * 1e6,
            f"best_staged_round={best_staged * 1e6:.0f}us "
            f"dispatches_per_round={fdpr} grid_steps={_grid_steps(fgrids)} "
            f"staged_pallas_grid_steps={staged_loads['pallas']} "
            f"wall_speedup={best_staged / t_fused:.2f}")
    # HARD regression guards (the 85 ms cliff): every pallas grid in the
    # batched send/merge kernels and the fused megakernel must be
    # K-independent — identical schedules at K=1 and K=16
    for phase in ("send", "merge", "fused"):
        g1, g16 = grids_by_k[1][phase], grids_by_k[16][phase]
        assert g1 == g16, (
            f"pallas {phase} grid scales with K ({g1} at K=1 vs {g16} at "
            f"K=16) — per-query tile re-streaming is back")
        assert g1, f"pallas {phase} traced no pallas_call (fallback?)"


def bench_scale(out, full=False):
    """Million-edge scale: MTEPS + measured bytes-per-edge per workload
    preset (the `scale` section of BENCH_sssp.json).

    Every preset is STREAM-built into ragged CSR-chunked shards — the
    memory path a 10M-edge graph must take. The 1e5 preset is additionally
    batch-built dense and solved both ways with hard asserts: ragged
    layout bytes strictly below dense, and distances bit-identical (the
    acceptance gate for the ragged layout family). The 1e6 preset is
    stream-built and measured (build time + bytes/edge) but solved only
    at `full=True`; 1e7 is `full=True` only — interpret-mode kernels are
    CPU-emulated, so its value is the LAYOUT numbers, not wall time."""
    rng = np.random.default_rng(31)
    # chunk size scales with the graph: EB rounding waste is ~EB/2 per
    # occupied tile, so small presets need small chunks to stay near the
    # CSR ideal while big ones amortize a larger (more kernel-friendly) EB
    TILES = {"scale-1e5": 128, "scale-1e6": 256, "scale-1e7": 512}
    for name in ("scale-1e5", "scale-1e6", "scale-1e7"):
        if name != "scale-1e5" and not full:
            if name == "scale-1e7":
                continue
        eb = TILES[name]
        tiles = dict(relax_eb=eb, send_eb=eb, merge_eb=eb)
        n, chunks = preset_edge_stream(name)
        P = 8
        t0 = time.perf_counter()
        sh = build_shards_stream(chunks, n, P, **tiles)
        t_build = time.perf_counter() - t0
        lb = sh.layout_bytes()
        out(f"scale[{name}][build]", t_build * 1e6,
            f"edges={lb['n_edges']} bytes_per_edge={lb['bytes_per_edge']:.2f} "
            f"ideal={lb['ideal_bytes_per_edge']:.1f} "
            f"ragged_bytes={lb['total_bytes']} dense_bytes={lb['dense_bytes']}")
        assert lb["total_bytes"] <= lb["dense_bytes"], (
            f"{name}: ragged layout ({lb['total_bytes']} B) larger than the "
            f"dense layout it replaces ({lb['dense_bytes']} B)")
        assert lb["bytes_per_edge"] <= 1.5 * lb["ideal_bytes_per_edge"], (
            f"{name}: measured {lb['bytes_per_edge']:.2f} B/edge exceeds "
            f"1.5x the CSR ideal ({lb['ideal_bytes_per_edge']:.1f} B/edge) "
            "— chunk rounding waste regressed")
        if name == "scale-1e5":
            # acceptance gate: dense twin must agree bit-for-bit, and the
            # ragged layout must be strictly smaller on this skewed graph.
            # The twin is materialized from the SAME stream (the streaming
            # generator's counter-keyed RNG differs from rmat_graph's
            # sequential draw, so preset_graph would be a different graph).
            _, chunks2 = preset_edge_stream(name)
            cs = list(chunks2)
            from repro.graph.structure import csr_from_coo
            g = csr_from_coo(np.concatenate([c[0] for c in cs]),
                             np.concatenate([c[1] for c in cs]),
                             np.concatenate([c[2] for c in cs]), n)
            dense = build_shards(g, P, enumerate_triangles=False, **tiles)
            dlb = dense.layout_bytes()
            assert lb["total_bytes"] < dlb["total_bytes"], (
                "ragged layout not smaller than dense on RMAT "
                f"({lb['total_bytes']} vs {dlb['total_bytes']} B)")
            sources = sorted(int(s) for s in
                             rng.choice(np.unique(np.asarray(g.src)), size=4,
                                        replace=False))
            cfg = SsspConfig(prune_online=False, local_solver="pallas",
                             send_backend="pallas", merge_backend="pallas")
            d_r, s_r = solve_sim_batch(sh, sources, cfg)
            d_d, s_d = solve_sim_batch(dense, sources, cfg)
            assert np.array_equal(np.asarray(d_r), np.asarray(d_d)), \
                "ragged solve lost bit-identity with dense"
            ts = []
            for _ in range(2):
                t0 = time.perf_counter()
                _, s_r = solve_sim_batch(sh, sources, cfg)
                ts.append(time.perf_counter() - t0)
            t = min(ts)
            mteps = int(s_r.relaxations) / t / 1e6
            out(f"scale[{name}][solve][K=4]", t * 1e6,
                f"mteps={mteps:.4f} rounds={int(s_r.rounds)} "
                f"ragged_vs_dense=bit-identical "
                f"mem_ratio={lb['total_bytes'] / dlb['total_bytes']:.3f}")
        elif full and name == "scale-1e6":
            # 1e7 stays build-only even at full: interpret-mode kernels
            # emulate every vector lane on CPU, so its solve measures the
            # emulator, not the layout
            source = int(np.asarray(sh.loc_src)[0, 0])
            cfg = SsspConfig(prune_online=False)
            t0 = time.perf_counter()
            _, stats = solve_sim(sh, source, cfg)
            t = time.perf_counter() - t0
            mteps = int(stats.relaxations) / t / 1e6
            out(f"scale[{name}][solve]", t * 1e6,
                f"mteps={mteps:.4f} rounds={int(stats.rounds)}")


# ------------------------------------------------------- regression gate ----

def check_against(baseline_path, records):
    """Compare this run's records against a committed baseline json.

    Fails (returns a list of violation strings) when a record present in
    BOTH runs regresses: MTEPS down more than 25%, or ANY increase in a
    recompile counter (recompiles are a correctness property of the warm
    paths — one is one too many). Records only one side has are ignored,
    so adding or retiring sections never breaks the gate."""
    import json as _json
    with open(baseline_path) as f:
        base = {r["name"]: r for r in _json.load(f)["benchmarks"]}
    _RECOMP_RE = re.compile(r"recompiles=(\d+)")
    violations = []
    for rec in records:
        b = base.get(rec["name"])
        if b is None:
            continue
        if "mteps" in rec and "mteps" in b and b["mteps"] > 0:
            ratio = rec["mteps"] / b["mteps"]
            if ratio < 0.75:
                violations.append(
                    f"{rec['name']}: MTEPS {b['mteps']:.4f} -> "
                    f"{rec['mteps']:.4f} ({ratio:.0%} of baseline, "
                    "floor 75%)")
        mb = _RECOMP_RE.search(b.get("derived", ""))
        mr = _RECOMP_RE.search(rec.get("derived", ""))
        if mb and mr and int(mr.group(1)) > int(mb.group(1)):
            violations.append(
                f"{rec['name']}: recompiles {mb.group(1)} -> {mr.group(1)}")
    return violations


def run_all(out):
    bench_scaling(out)
    bench_trishla(out)
    bench_toka(out)
    bench_local_solver(out)
    bench_pallas_solver(out)
    bench_batch_throughput(out)
    bench_engine_serving(out)
    bench_warm_start(out)
    bench_faults(out)
    bench_async_scaling(out)
    bench_phase_breakdown(out)
    bench_scale(out)


# ---------------------------------------------------------------- smoke ----

SMOKE_GRAPHS = {
    # same shapes as BENCH_GRAPHS, scaled to CI seconds: the smoke profile
    # exists to catch wiring rot (recompiles on warm paths, broken bench
    # sections), not to track performance numbers.
    "graph1-like": lambda: rmat_graph(scale=8, edge_factor=2, seed=1),
    "graph2-like": lambda: road_grid_graph(side=16, seed=2),
    "graph3-like": lambda: rmat_graph(scale=7, edge_factor=8, seed=3),
}


def run_smoke(out):
    """CI-sized subset: the engine-serving, warm-start, faults,
    async-scaling, and phase-breakdown sections on tiny graphs. These
    sections carry hard asserts (recompiles == 0 on warm paths, warm
    bit-identity, zero-round cache hits, faulted + async bit-identity,
    monotone modeled async speedup, pallas send/merge within 2x of XLA
    at K=16), so the smoke job is a correctness gate as well as an
    artifact producer."""
    global BENCH_GRAPHS
    full = BENCH_GRAPHS
    BENCH_GRAPHS = SMOKE_GRAPHS
    # distinct record names: smoke numbers must never clobber the tracked
    # full-size perf trajectory when the merged json is written locally
    def smoke_out(name, us, derived=""):
        out(f"smoke/{name}", us, derived)
    try:
        bench_engine_serving(smoke_out)
        bench_warm_start(smoke_out)
        bench_faults(smoke_out)
        bench_async_scaling(smoke_out)
        bench_phase_breakdown(smoke_out)
    finally:
        BENCH_GRAPHS = full


def main(argv=None):
    import argparse
    import os
    import sys

    # script mode (`python benchmarks/sssp_bench.py`) puts benchmarks/ on
    # sys.path, not the repo root the `benchmarks.run` import needs
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    if root not in sys.path:
        sys.path.insert(0, root)

    p = argparse.ArgumentParser(description="SP-Async SSSP benchmarks")
    p.add_argument("--smoke", action="store_true",
                   help="tiny CI profile (seconds): engine_serving + "
                        "warm_start sections with recompile/bit-identity "
                        "asserts")
    p.add_argument("--scale", action="store_true",
                   help="only the scale section (stream-built ragged "
                        "workload presets: MTEPS + bytes-per-edge, with "
                        "the 1e5 ragged-vs-dense bit-identity gate)")
    p.add_argument("--scale-full", action="store_true",
                   help="scale section including the 1e6 solve and the "
                        "1e7 stream build (minutes; nightly profile)")
    p.add_argument("--check-against", default=None, metavar="PATH",
                   help="committed baseline json to gate this run against: "
                        "fail on any shared record losing >25%% MTEPS or "
                        "gaining recompiles")
    p.add_argument("--out", default=None,
                   help="output json (default: BENCH_sssp.json for the "
                        "full run; the gitignored BENCH_sssp.smoke.json "
                        "for --smoke/--scale, so local smoke runs never "
                        "dirty the tracked perf trajectory)")
    args = p.parse_args(argv)
    from benchmarks.run import _RECORDS, _out, _write_json
    if args.scale or args.scale_full:
        bench_scale(_out, full=args.scale_full)
        _write_json(args.out or "BENCH_sssp.smoke.json")
    elif args.smoke:
        run_smoke(_out)
        _write_json(args.out or "BENCH_sssp.smoke.json")
    else:
        run_all(_out)
        _write_json(args.out or "BENCH_sssp.json")
    if args.check_against:
        violations = check_against(args.check_against, _RECORDS)
        if violations:
            print("# PERF REGRESSION vs", args.check_against)
            for v in violations:
                print("#  ", v)
            sys.exit(1)
        print(f"# perf gate vs {args.check_against}: ok")


if __name__ == "__main__":
    main()
