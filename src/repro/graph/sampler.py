"""Layer-wise uniform neighbor sampler (GraphSAGE-style) for minibatch GNN
training — required by the ``minibatch_lg`` shape (fanout 15-10).

Host-side numpy over a CSR adjacency; emits padded, fixed-shape subgraph
batches so the jitted model never retraces. Matches the deployment shape:
sampling runs on host CPUs of each worker while the accelerator consumes
the previous batch.
"""
from __future__ import annotations

import numpy as np

from repro.graph.structure import Graph


class NeighborSampler:
    def __init__(self, g: Graph, fanouts: tuple[int, ...], seed: int = 0):
        src = np.asarray(g.src[: g.n_edges], np.int64)
        dst = np.asarray(g.dst[: g.n_edges], np.int64)
        self.n = g.n_vertices
        order = np.argsort(src, kind="stable")
        self.dst_sorted = dst[order]
        self.row_ptr = np.zeros(self.n + 1, np.int64)
        np.add.at(self.row_ptr, src + 1, 1)
        self.row_ptr = np.cumsum(self.row_ptr)
        self.fanouts = tuple(fanouts)
        self.rng = np.random.default_rng(seed)

    def max_nodes(self, batch_nodes: int) -> int:
        m = batch_nodes
        total = batch_nodes
        for f in self.fanouts:
            m *= f
            total += m
        return total

    def max_edges(self, batch_nodes: int) -> int:
        m, total = batch_nodes, 0
        for f in self.fanouts:
            total += m * f
            m *= f
        return total

    def sample(self, seeds: np.ndarray):
        """Returns (nodes [max_nodes], src [max_e], dst [max_e], n_real_nodes).

        src/dst are *local* indices into ``nodes``; padding uses max_nodes
        (the sentinel convention shared with the models)."""
        B = len(seeds)
        max_n, max_e = self.max_nodes(B), self.max_edges(B)
        nodes = list(seeds)
        local_of = {int(v): i for i, v in enumerate(seeds)}
        srcs, dsts = [], []
        frontier = list(seeds)
        for f in self.fanouts:
            nxt = []
            for u in frontier:
                lo, hi = self.row_ptr[u], self.row_ptr[u + 1]
                deg = hi - lo
                if deg == 0:
                    continue
                take = self.rng.integers(lo, hi, size=min(f, deg))
                for e in take:
                    v = int(self.dst_sorted[e])
                    if v not in local_of:
                        local_of[v] = len(nodes)
                        nodes.append(v)
                        nxt.append(v)
                    # message flows neighbor -> seed direction (v -> u)
                    srcs.append(local_of[v])
                    dsts.append(local_of[u])
            frontier = nxt
        n_real = len(nodes)
        nodes_pad = np.full(max_n, self.n, np.int64)
        nodes_pad[:n_real] = nodes
        src_pad = np.full(max_e, max_n, np.int64)
        dst_pad = np.full(max_e, max_n, np.int64)
        src_pad[: len(srcs)] = srcs
        dst_pad[: len(dsts)] = dsts
        return nodes_pad, src_pad, dst_pad, n_real
