from repro.graph.structure import Graph, PartitionedGraph, csr_from_coo
from repro.graph.generators import rmat_graph, road_grid_graph, random_graph, assign_weights
from repro.graph.reference import dijkstra_reference, bellman_ford_reference
