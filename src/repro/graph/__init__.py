from repro.graph.structure import Graph, PartitionedGraph, csr_from_coo
from repro.graph.generators import (GENERATORS, SCALE_PRESETS, assign_weights,
                                    edge_chunks_of, get_generator,
                                    ogbn_products_graph, preset_edge_stream,
                                    preset_graph, random_graph,
                                    register_generator, rmat_edge_stream,
                                    rmat_graph, road_grid_graph)
from repro.graph.reference import dijkstra_reference, bellman_ford_reference
