"""Reference SSSP oracles (pure numpy, host-side).

Used by tests/benchmarks as ground truth for the distributed implementation.
"""
from __future__ import annotations

import heapq

import numpy as np

from repro.graph.structure import Graph, graph_to_numpy


def dijkstra_reference(g: Graph, source: int) -> np.ndarray:
    """Binary-heap Dijkstra. O((V+E) log V)."""
    src, dst, w = graph_to_numpy(g)
    n = g.n_vertices
    # CSR build
    order = np.argsort(src, kind="stable")
    src, dst, w = src[order], dst[order], w[order]
    row_ptr = np.zeros(n + 1, np.int64)
    np.add.at(row_ptr, src + 1, 1)
    row_ptr = np.cumsum(row_ptr)
    dist = np.full(n, np.inf, np.float64)
    dist[source] = 0.0
    done = np.zeros(n, bool)
    heap = [(0.0, source)]
    while heap:
        d, u = heapq.heappop(heap)
        if done[u]:
            continue
        done[u] = True
        for e in range(row_ptr[u], row_ptr[u + 1]):
            v = dst[e]
            nd = d + w[e]
            if nd < dist[v]:
                dist[v] = nd
                heapq.heappush(heap, (nd, v))
    return dist.astype(np.float32)


def bellman_ford_reference(g: Graph, source: int, max_iters: int | None = None) -> np.ndarray:
    """Vectorized Bellman-Ford (numpy). Ground truth #2 / convergence check."""
    src, dst, w = graph_to_numpy(g)
    n = g.n_vertices
    dist = np.full(n, np.inf, np.float64)
    dist[source] = 0.0
    iters = max_iters if max_iters is not None else n
    for _ in range(iters):
        cand = dist[src] + w
        new = dist.copy()
        np.minimum.at(new, dst, cand)
        if np.array_equal(new, dist):
            break
        dist = new
    return dist.astype(np.float32)
