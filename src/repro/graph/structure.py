"""Static-shape graph containers.

Everything is padded to fixed sizes so the structures flow through jit /
shard_map without retracing. Edges are directed; an undirected graph stores
both directions explicitly.

Conventions
-----------
- ``src``/``dst`` are int32 vertex ids, ``weight`` float32.
- Padding edges use ``src = dst = n_vertices`` (a sentinel vertex) and
  ``weight = +inf`` so they never win a min-plus relaxation; a boolean
  ``valid`` mask is also kept for reductions that need it.
- CSR is "sorted-COO + row offsets": edges sorted by src, plus
  ``row_ptr[n_vertices + 1]``.
"""
from __future__ import annotations

import dataclasses
import jax
import jax.numpy as jnp
import numpy as np

INF = jnp.float32(jnp.inf)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Graph:
    """A whole (unpartitioned) graph in padded COO, sorted by src (CSR-like)."""

    src: jax.Array          # [e_pad] int32
    dst: jax.Array          # [e_pad] int32
    weight: jax.Array       # [e_pad] float32
    row_ptr: jax.Array      # [n+1] int32 (offsets into sorted edge list)
    n_vertices: int = dataclasses.field(metadata=dict(static=True))
    n_edges: int = dataclasses.field(metadata=dict(static=True))

    @property
    def e_pad(self) -> int:
        return self.src.shape[0]

    @property
    def valid(self) -> jax.Array:
        return jnp.arange(self.e_pad, dtype=jnp.int32) < self.n_edges


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PartitionedGraph:
    """1-D block partition of a Graph over P shards (paper §III.A).

    Vertex v is owned by shard ``v // block`` with ``block = ceil(n/P)``.
    Every per-shard array is padded to the max across shards so the stacked
    [P, ...] arrays are rectangular and can be sharded with shard_map.

    Edge arrays are *local* COO sorted by local src:
      - ``src_local``: src id within the shard (0..block-1)
      - ``dst_global``: global dst id (may be owned by another shard)
      - ``dst_owner``: shard id owning dst
      - ``dst_local``: dst id within its owner's block
    """

    src_local: jax.Array    # [P, e_max] int32
    dst_global: jax.Array   # [P, e_max] int32
    dst_owner: jax.Array    # [P, e_max] int32
    dst_local: jax.Array    # [P, e_max] int32
    weight: jax.Array       # [P, e_max] float32
    valid: jax.Array        # [P, e_max] bool
    is_cut: jax.Array       # [P, e_max] bool  (dst owned by another shard)
    n_vertices: int = dataclasses.field(metadata=dict(static=True))
    n_edges: int = dataclasses.field(metadata=dict(static=True))
    n_parts: int = dataclasses.field(metadata=dict(static=True))
    block: int = dataclasses.field(metadata=dict(static=True))

    @property
    def e_max(self) -> int:
        return self.src_local.shape[1]

    @property
    def n_cut_edges(self):
        return int(np.asarray(jnp.sum(jnp.where(self.valid, self.is_cut, False))))


def csr_from_coo(src: np.ndarray, dst: np.ndarray, weight: np.ndarray,
                 n_vertices: int, e_pad: int | None = None,
                 dedup: bool = True) -> Graph:
    """Sort COO by (src, dst), optionally dedup keeping min weight, pad."""
    src = np.asarray(src, np.int64)
    dst = np.asarray(dst, np.int64)
    weight = np.asarray(weight, np.float32)
    order = np.lexsort((dst, src))
    src, dst, weight = src[order], dst[order], weight[order]
    if dedup and len(src):
        # keep min weight among duplicate (src, dst)
        key = src * n_vertices + dst
        # within equal keys, keep the smallest weight: sort by (key, weight)
        o2 = np.lexsort((weight, key))
        key, src, dst, weight = key[o2], src[o2], dst[o2], weight[o2]
        keep = np.ones(len(key), bool)
        keep[1:] = key[1:] != key[:-1]
        src, dst, weight = src[keep], dst[keep], weight[keep]
    n_edges = len(src)
    if e_pad is None:
        e_pad = max(n_edges, 1)
    assert e_pad >= n_edges
    pad = e_pad - n_edges
    src_p = np.concatenate([src, np.full(pad, n_vertices, np.int64)])
    dst_p = np.concatenate([dst, np.full(pad, n_vertices, np.int64)])
    w_p = np.concatenate([weight, np.full(pad, np.inf, np.float32)])
    row_ptr = np.zeros(n_vertices + 1, np.int64)
    np.add.at(row_ptr, src + 1, 1)
    row_ptr = np.cumsum(row_ptr)
    return Graph(
        src=jnp.asarray(src_p, jnp.int32),
        dst=jnp.asarray(dst_p, jnp.int32),
        weight=jnp.asarray(w_p, jnp.float32),
        row_ptr=jnp.asarray(row_ptr, jnp.int32),
        n_vertices=int(n_vertices),
        n_edges=int(n_edges),
    )


def graph_to_numpy(g: Graph):
    """Valid (src, dst, weight) as numpy."""
    e = g.n_edges
    return (np.asarray(g.src[:e]), np.asarray(g.dst[:e]), np.asarray(g.weight[:e]))
