"""Graph generators.

The paper evaluates on ParMat/R-MAT synthetic graphs plus the USA road map.
We provide:
  - ``rmat_graph``: R-MAT (the generator behind ParMat) — scale-free graphs.
  - ``road_grid_graph``: 2-D grid with diagonal shortcuts — road-network-like
    (bounded degree, large diameter), the Graph2 stand-in.
  - ``random_graph``: Erdos-Renyi-ish uniform random edges.
  - ``assign_weights``: U[1, 20) weights, matching the paper's setup.
All generation is numpy (host-side, one-time cost, same as the paper's
"graph processing" phase).
"""
from __future__ import annotations

import numpy as np

from repro.graph.structure import Graph, csr_from_coo


def assign_weights(n_edges: int, rng: np.random.Generator,
                   low: float = 1.0, high: float = 20.0) -> np.ndarray:
    """Paper §IV.A: pseudo-random weights uniform in [1, 20)."""
    return rng.uniform(low, high, size=n_edges).astype(np.float32)


def rmat_graph(scale: int, edge_factor: int = 16, seed: int = 0,
               a: float = 0.57, b: float = 0.19, c: float = 0.19,
               undirected: bool = True, e_pad: int | None = None) -> Graph:
    """R-MAT generator (Graph500 parameters by default). n = 2**scale."""
    rng = np.random.default_rng(seed)
    n = 1 << scale
    m = n * edge_factor
    src = np.zeros(m, np.int64)
    dst = np.zeros(m, np.int64)
    for level in range(scale):
        r = rng.random(m)
        # quadrant probabilities a, b, c, d
        go_right = (r >= a) & (r < a + b) | (r >= a + b + c)
        go_down = r >= a + b
        src |= (go_down.astype(np.int64) << (scale - 1 - level))
        dst |= (go_right.astype(np.int64) << (scale - 1 - level))
    # permute vertex ids to break degree locality
    perm = rng.permutation(n)
    src, dst = perm[src], perm[dst]
    keep = src != dst  # drop self loops
    src, dst = src[keep], dst[keep]
    w = assign_weights(len(src), rng)
    if undirected:
        src, dst = np.concatenate([src, dst]), np.concatenate([dst, src])
        w = np.concatenate([w, w])
    return csr_from_coo(src, dst, w, n, e_pad=e_pad)


def road_grid_graph(side: int, seed: int = 0, diag_prob: float = 0.1,
                    e_pad: int | None = None) -> Graph:
    """side×side grid, bidirectional edges, a few diagonals. Road-like."""
    rng = np.random.default_rng(seed)
    n = side * side
    ii, jj = np.meshgrid(np.arange(side), np.arange(side), indexing="ij")
    vid = (ii * side + jj).ravel()
    srcs, dsts = [], []
    # right and down neighbours
    right = vid.reshape(side, side)[:, :-1].ravel()
    srcs.append(right)
    dsts.append(right + 1)
    down = vid.reshape(side, side)[:-1, :].ravel()
    srcs.append(down)
    dsts.append(down + side)
    # sparse diagonals
    diag = vid.reshape(side, side)[:-1, :-1].ravel()
    mask = rng.random(diag.shape[0]) < diag_prob
    srcs.append(diag[mask])
    dsts.append(diag[mask] + side + 1)
    src = np.concatenate(srcs)
    dst = np.concatenate(dsts)
    w = assign_weights(len(src), rng)
    src, dst = np.concatenate([src, dst]), np.concatenate([dst, src])
    w = np.concatenate([w, w])
    return csr_from_coo(src, dst, w, n, e_pad=e_pad)


def random_graph(n: int, m: int, seed: int = 0, undirected: bool = True,
                 e_pad: int | None = None, ensure_connected_from: int | None = 0) -> Graph:
    """Uniform random directed multigraph (deduped), optional spanning chain.

    ``ensure_connected_from=s`` adds a random permutation chain so every
    vertex is reachable from s — keeps correctness tests deterministic
    (finite distances everywhere).
    """
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n, m)
    keep = src != dst
    src, dst = src[keep], dst[keep]
    if ensure_connected_from is not None:
        order = rng.permutation(n)
        pos = int(np.where(order == ensure_connected_from)[0][0])
        order = np.roll(order, -pos)  # chain starts at the source vertex
        src = np.concatenate([src, order[:-1]])
        dst = np.concatenate([dst, order[1:]])
    w = assign_weights(len(src), rng)
    if undirected:
        src, dst = np.concatenate([src, dst]), np.concatenate([dst, src])
        w = np.concatenate([w, w])
    return csr_from_coo(src, dst, w, n, e_pad=e_pad)


# ---- generator registry + Graph500-style scale presets --------------------
# Benchmarks and the CI scale gate refer to workloads by name; registering a
# generator here makes it addressable from ``--graph name`` style CLIs without
# the caller importing the module that defines it.

GENERATORS: dict[str, object] = {}


def register_generator(name: str):
    """Decorator: register ``fn(**kwargs) -> Graph`` under ``name``."""
    def deco(fn):
        GENERATORS[name] = fn
        return fn
    return deco


def get_generator(name: str):
    if name not in GENERATORS:
        raise KeyError(f"unknown generator {name!r}: have "
                       f"{sorted(GENERATORS)}")
    return GENERATORS[name]


register_generator("rmat")(rmat_graph)
register_generator("road_grid")(road_grid_graph)
register_generator("random")(random_graph)

# Graph500-flavoured presets: (generator, kwargs) pairs sized by DIRECTED
# edge count after undirected doubling (~1e5 / 1e6 / 1e7). The scale gate
# in CI runs "scale-1e5"; the nightly bench can take the larger two.
SCALE_PRESETS = {
    "scale-1e5": ("rmat", dict(scale=13, edge_factor=8, seed=500)),
    "scale-1e6": ("rmat", dict(scale=16, edge_factor=8, seed=600)),
    "scale-1e7": ("rmat", dict(scale=19, edge_factor=10, seed=700)),
}


def preset_graph(name: str, **overrides) -> Graph:
    """Materialize a ``SCALE_PRESETS`` workload (small/medium only — for
    1e7+ prefer ``preset_edge_stream`` + ``build_shards_stream``)."""
    gen, kw = SCALE_PRESETS[name]
    return get_generator(gen)(**{**kw, **overrides})


def rmat_edge_stream(scale: int, edge_factor: int = 16, seed: int = 0,
                     a: float = 0.57, b: float = 0.19, c: float = 0.19,
                     undirected: bool = True, chunk_edges: int = 1 << 18):
    """R-MAT as an iterator of ``(src, dst, w)`` chunks — the streaming twin
    of ``rmat_graph`` for graphs too large to materialize as one COO block.

    R-MAT edges are iid given the quadrant probabilities, so each chunk is
    drawn from its own counter-keyed RNG stream: the edge SET depends only on
    (seed, chunk_edges), never on how far the consumer iterated. The vertex
    permutation is drawn up front from the seed (O(n) memory — the same
    budget any partitioner needs for the per-vertex distance array).
    """
    n = 1 << scale
    m = n * edge_factor
    perm = np.random.default_rng((seed, 0)).permutation(n)
    for start in range(0, m, chunk_edges):
        cm = min(chunk_edges, m - start)
        rng = np.random.default_rng((seed, 1 + start // chunk_edges))
        src = np.zeros(cm, np.int64)
        dst = np.zeros(cm, np.int64)
        for level in range(scale):
            r = rng.random(cm)
            go_right = (r >= a) & (r < a + b) | (r >= a + b + c)
            go_down = r >= a + b
            src |= (go_down.astype(np.int64) << (scale - 1 - level))
            dst |= (go_right.astype(np.int64) << (scale - 1 - level))
        src, dst = perm[src], perm[dst]
        keep = src != dst
        src, dst = src[keep], dst[keep]
        w = assign_weights(len(src), rng)
        if undirected:
            src, dst = np.concatenate([src, dst]), np.concatenate([dst, src])
            w = np.concatenate([w, w])
        if len(src):
            yield src, dst, w


def preset_edge_stream(name: str, chunk_edges: int = 1 << 18):
    """Streaming form of a ``SCALE_PRESETS`` workload. Returns
    ``(n_vertices, iterator_of_chunks)``."""
    gen, kw = SCALE_PRESETS[name]
    if gen != "rmat":
        raise ValueError(f"preset {name!r} uses generator {gen!r}, which has "
                         "no streaming form")
    return 1 << kw["scale"], rmat_edge_stream(chunk_edges=chunk_edges, **kw)


def edge_chunks_of(g: Graph, chunk_edges: int = 1 << 18):
    """Chunk iterator over a materialized Graph's valid edges — lets the
    streaming builder be exercised (and tested) against batch inputs."""
    v = np.asarray(g.valid)
    src, dst = np.asarray(g.src)[v], np.asarray(g.dst)[v]
    w = np.asarray(g.weight)[v]
    for i in range(0, len(src), chunk_edges):
        yield src[i:i + chunk_edges], dst[i:i + chunk_edges], w[i:i + chunk_edges]


def ogbn_products_graph(root: str = "data/ogbn_products",
                        e_pad: int | None = None) -> Graph:
    """Load ogbn-products (2.4M vertices, 123M edges) from a local extract.

    Expects ``<root>/edge.npy`` (or ``edge_index.npy``) holding an int
    ``[2, E]`` (or ``[E, 2]``) edge index — the format produced by exporting
    ``ogb.nodeproppred.NodePropPredDataset('ogbn-products')``'s graph dict.
    No network access is attempted: this container is offline, so a missing
    file raises with download instructions instead of fetching.

    Edges get U[1, 20) weights (the dataset is unweighted; the paper's
    weight model, see ``assign_weights``) and are symmetrized by
    ``csr_from_coo`` dedup.
    """
    import os
    cand = [os.path.join(root, "edge.npy"),
            os.path.join(root, "edge_index.npy")]
    path = next((p for p in cand if os.path.exists(p)), None)
    if path is None:
        raise FileNotFoundError(
            f"ogbn-products edge index not found (looked for {cand}). "
            "On a machine with network access run:\n"
            "  python -c \"from ogb.nodeproppred import NodePropPredDataset; "
            "import numpy as np; d = NodePropPredDataset('ogbn-products'); "
            "np.save('edge.npy', d[0][0]['edge_index'])\"\n"
            f"and place edge.npy under {root}/")
    ei = np.load(path, mmap_mode="r")
    if ei.shape[0] != 2:
        ei = ei.T
    src = np.asarray(ei[0], np.int64)
    dst = np.asarray(ei[1], np.int64)
    n = int(max(src.max(), dst.max())) + 1
    w = assign_weights(len(src), np.random.default_rng(0))
    src, dst = np.concatenate([src, dst]), np.concatenate([dst, src])
    w = np.concatenate([w, w])
    return csr_from_coo(src, dst, w, n, e_pad=e_pad)


register_generator("ogbn-products")(ogbn_products_graph)


# ---- paper graph descriptors (full-scale; used by the dry-run only) -------

PAPER_GRAPHS = {
    # name: (n_vertices, n_edges, comment)
    "graph1": (391_529, 873_775, "small synthetic (ParMat)"),
    "graph2": (23_947_347, 58_333_344, "USA road network"),
    "graph3": (3_072_441, 117_185_083, "Orkut-like social network"),
    "graph4": (41_700_000, 1_470_000_000, "Twitter-like (41.7M v, 1.47B e)"),
}
