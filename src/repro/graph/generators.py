"""Graph generators.

The paper evaluates on ParMat/R-MAT synthetic graphs plus the USA road map.
We provide:
  - ``rmat_graph``: R-MAT (the generator behind ParMat) — scale-free graphs.
  - ``road_grid_graph``: 2-D grid with diagonal shortcuts — road-network-like
    (bounded degree, large diameter), the Graph2 stand-in.
  - ``random_graph``: Erdos-Renyi-ish uniform random edges.
  - ``assign_weights``: U[1, 20) weights, matching the paper's setup.
All generation is numpy (host-side, one-time cost, same as the paper's
"graph processing" phase).
"""
from __future__ import annotations

import numpy as np

from repro.graph.structure import Graph, csr_from_coo


def assign_weights(n_edges: int, rng: np.random.Generator,
                   low: float = 1.0, high: float = 20.0) -> np.ndarray:
    """Paper §IV.A: pseudo-random weights uniform in [1, 20)."""
    return rng.uniform(low, high, size=n_edges).astype(np.float32)


def rmat_graph(scale: int, edge_factor: int = 16, seed: int = 0,
               a: float = 0.57, b: float = 0.19, c: float = 0.19,
               undirected: bool = True, e_pad: int | None = None) -> Graph:
    """R-MAT generator (Graph500 parameters by default). n = 2**scale."""
    rng = np.random.default_rng(seed)
    n = 1 << scale
    m = n * edge_factor
    src = np.zeros(m, np.int64)
    dst = np.zeros(m, np.int64)
    for level in range(scale):
        r = rng.random(m)
        # quadrant probabilities a, b, c, d
        go_right = (r >= a) & (r < a + b) | (r >= a + b + c)
        go_down = r >= a + b
        src |= (go_down.astype(np.int64) << (scale - 1 - level))
        dst |= (go_right.astype(np.int64) << (scale - 1 - level))
    # permute vertex ids to break degree locality
    perm = rng.permutation(n)
    src, dst = perm[src], perm[dst]
    keep = src != dst  # drop self loops
    src, dst = src[keep], dst[keep]
    w = assign_weights(len(src), rng)
    if undirected:
        src, dst = np.concatenate([src, dst]), np.concatenate([dst, src])
        w = np.concatenate([w, w])
    return csr_from_coo(src, dst, w, n, e_pad=e_pad)


def road_grid_graph(side: int, seed: int = 0, diag_prob: float = 0.1,
                    e_pad: int | None = None) -> Graph:
    """side×side grid, bidirectional edges, a few diagonals. Road-like."""
    rng = np.random.default_rng(seed)
    n = side * side
    ii, jj = np.meshgrid(np.arange(side), np.arange(side), indexing="ij")
    vid = (ii * side + jj).ravel()
    srcs, dsts = [], []
    # right and down neighbours
    right = vid.reshape(side, side)[:, :-1].ravel()
    srcs.append(right)
    dsts.append(right + 1)
    down = vid.reshape(side, side)[:-1, :].ravel()
    srcs.append(down)
    dsts.append(down + side)
    # sparse diagonals
    diag = vid.reshape(side, side)[:-1, :-1].ravel()
    mask = rng.random(diag.shape[0]) < diag_prob
    srcs.append(diag[mask])
    dsts.append(diag[mask] + side + 1)
    src = np.concatenate(srcs)
    dst = np.concatenate(dsts)
    w = assign_weights(len(src), rng)
    src, dst = np.concatenate([src, dst]), np.concatenate([dst, src])
    w = np.concatenate([w, w])
    return csr_from_coo(src, dst, w, n, e_pad=e_pad)


def random_graph(n: int, m: int, seed: int = 0, undirected: bool = True,
                 e_pad: int | None = None, ensure_connected_from: int | None = 0) -> Graph:
    """Uniform random directed multigraph (deduped), optional spanning chain.

    ``ensure_connected_from=s`` adds a random permutation chain so every
    vertex is reachable from s — keeps correctness tests deterministic
    (finite distances everywhere).
    """
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n, m)
    keep = src != dst
    src, dst = src[keep], dst[keep]
    if ensure_connected_from is not None:
        order = rng.permutation(n)
        pos = int(np.where(order == ensure_connected_from)[0][0])
        order = np.roll(order, -pos)  # chain starts at the source vertex
        src = np.concatenate([src, order[:-1]])
        dst = np.concatenate([dst, order[1:]])
    w = assign_weights(len(src), rng)
    if undirected:
        src, dst = np.concatenate([src, dst]), np.concatenate([dst, src])
        w = np.concatenate([w, w])
    return csr_from_coo(src, dst, w, n, e_pad=e_pad)


# ---- paper graph descriptors (full-scale; used by the dry-run only) -------

PAPER_GRAPHS = {
    # name: (n_vertices, n_edges, comment)
    "graph1": (391_529, 873_775, "small synthetic (ParMat)"),
    "graph2": (23_947_347, 58_333_344, "USA road network"),
    "graph3": (3_072_441, 117_185_083, "Orkut-like social network"),
    "graph4": (41_700_000, 1_470_000_000, "Twitter-like (41.7M v, 1.47B e)"),
}
