"""Fault-tolerant pytree checkpointing with elastic restore.

Layout (one directory per step, atomic via rename-on-commit):

  <dir>/step_000123.tmp/...   while writing
  <dir>/step_000123/
      meta.json               {step, leaf treedef, shapes, dtypes}
      leaf_00000.npy ...      one .npy per leaf (host-gathered)

Restart semantics (what a 1000-node deployment needs):
  - save is crash-safe: a partially-written step never has the committed
    name, so ``latest_step`` only ever sees complete checkpoints;
  - ``restore_checkpoint`` takes the *target* abstract tree + shardings and
    puts each leaf onto the live mesh (``jax.device_put`` with the target
    NamedSharding) — the checkpoint is layout-agnostic, so restore works
    across device-count changes (elastic resume after losing a pod);
  - ``CheckpointManager`` keeps the newest K steps and prunes older ones.

On a real multi-host cluster each host would write its addressable shards
(process-local files) — single-process here, so leaves are gathered; the
meta/commit protocol is the part that carries over unchanged.
"""
from __future__ import annotations

import json
import os
import shutil

import jax
import numpy as np


def _leaves_and_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten(tree)
    return flat, treedef


def save_checkpoint(directory: str, step: int, tree) -> str:
    os.makedirs(directory, exist_ok=True)
    name = f"step_{step:08d}"
    tmp = os.path.join(directory, name + ".tmp")
    final = os.path.join(directory, name)
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    leaves, treedef = _leaves_and_paths(tree)
    meta = {"step": step, "n_leaves": len(leaves),
            "treedef": str(treedef),
            "shapes": [list(np.shape(l)) for l in leaves],
            "dtypes": [str(np.asarray(l).dtype) if not hasattr(l, "dtype")
                       else str(l.dtype) for l in leaves]}
    for i, leaf in enumerate(leaves):
        np.save(os.path.join(tmp, f"leaf_{i:05d}.npy"),
                np.asarray(jax.device_get(leaf)))
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)          # atomic commit
    return final


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore_checkpoint(directory: str, step: int, target_tree,
                       shardings=None):
    """target_tree: pytree with the wanted structure (arrays or structs).
    shardings: optional matching pytree of NamedSharding for elastic
    placement onto the live mesh."""
    path = os.path.join(directory, f"step_{step:08d}")
    flat_t, treedef = jax.tree_util.tree_flatten(target_tree)
    leaves = []
    for i, t in enumerate(flat_t):
        arr = np.load(os.path.join(path, f"leaf_{i:05d}.npy"))
        want_dtype = getattr(t, "dtype", arr.dtype)
        arr = arr.astype(want_dtype)
        leaves.append(arr)
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        tree = jax.tree_util.tree_map(
            lambda x, s: jax.device_put(x, s), tree, shardings)
    return tree


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep

    def save(self, step: int, tree):
        path = save_checkpoint(self.directory, step, tree)
        self._prune()
        return path

    def _prune(self):
        steps = sorted([int(d.split("_")[1]) for d in os.listdir(self.directory)
                        if d.startswith("step_") and not d.endswith(".tmp")])
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"))

    def latest(self) -> int | None:
        return latest_step(self.directory)

    def restore(self, target_tree, shardings=None, step: int | None = None):
        s = step if step is not None else self.latest()
        if s is None:
            return None, None
        return restore_checkpoint(self.directory, s, target_tree, shardings), s
