"""AutoInt [arXiv:1810.11921]: self-attention feature interaction over
sparse-field embeddings, with EmbeddingBag lookup (take + segment/masked
sum — JAX has no native EmbeddingBag; see kernels/embedding_bag for the
Pallas variant of the same op).

The embedding table is the system's memory hot spot: one combined table
[n_fields * vocab_per_field, d] row-sharded over the model axis. Lookups
are batch-sharded; GSPMD routes the gather.

Steps: train (BCE), serve (sigmoid scores), retrieval (query embedding vs
10^6 candidate vectors — one batched matmul + top-k, never a loop).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import MeshAxes
from repro.models.params import ParamDef
from repro.models.gnn import mlp_defs, mlp_apply


@dataclasses.dataclass(frozen=True)
class AutoIntConfig:
    name: str = "autoint"
    n_sparse: int = 39
    vocab_per_field: int = 1_000_000
    embed_dim: int = 16
    n_attn_layers: int = 3
    n_heads: int = 2
    d_attn: int = 32
    multi_hot: int = 1           # bag length per field (1 = one-hot)
    d_retrieval: int = 64

    @property
    def total_vocab(self):
        return self.n_sparse * self.vocab_per_field


def autoint_param_defs(cfg: AutoIntConfig, ax: MeshAxes):
    D, A, H = cfg.embed_dim, cfg.d_attn, cfg.n_heads
    layers = []
    d_in = D
    for _ in range(cfg.n_attn_layers):
        layers.append(dict(
            wq=ParamDef((d_in, H * A), P(None, None)),
            wk=ParamDef((d_in, H * A), P(None, None)),
            wv=ParamDef((d_in, H * A), P(None, None)),
            wres=ParamDef((d_in, H * A), P(None, None)),
        ))
        d_in = H * A
    return dict(
        table=ParamDef((cfg.total_vocab, D), P(ax.model, None),
                       init="embed", scale=0.01),
        layers=layers,
        head=mlp_defs([cfg.n_sparse * d_in, 64, 1]),
        retr_proj=mlp_defs([cfg.n_sparse * d_in, cfg.d_retrieval]),
    )


def _embed_fields(params, idx, cfg: AutoIntConfig):
    """idx: [B, F, L] global row ids (sentinel total_vocab = padding).
    EmbeddingBag (sum) per field -> [B, F, D]."""
    V = cfg.total_vocab
    valid = idx < V
    rows = jnp.take(params["table"], jnp.minimum(idx, V - 1), axis=0)
    rows = jnp.where(valid[..., None], rows, 0.0)
    return jnp.sum(rows, axis=2)


def autoint_embed(params, batch, cfg: AutoIntConfig, ax: MeshAxes,
                  batch_axes=None):
    """batch_axes: mesh axes to shard B over (None = replicated, for the
    B=1 retrieval query)."""
    bspec = P(batch_axes, None, None)
    x = _embed_fields(params, batch["sparse_idx"], cfg)      # [B, F, D]
    x = lax.with_sharding_constraint(x, bspec)
    B, F, _ = x.shape
    H, A = cfg.n_heads, cfg.d_attn
    for lp in params["layers"]:
        q = (x @ lp["wq"]).reshape(B, F, H, A)
        k = (x @ lp["wk"]).reshape(B, F, H, A)
        v = (x @ lp["wv"]).reshape(B, F, H, A)
        s = jnp.einsum("bfha,bgha->bhfg", q, k) / (A ** 0.5)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhfg,bgha->bfha", p, v).reshape(B, F, H * A)
        x = jax.nn.relu(o + x @ lp["wres"])
        x = lax.with_sharding_constraint(x, bspec)
    return x.reshape(B, -1)


def autoint_logit(params, batch, cfg, ax):
    flat = autoint_embed(params, batch, cfg, ax, batch_axes=ax.data)
    return mlp_apply(params["head"], flat, 2)[:, 0]


def autoint_loss(params, batch, cfg, ax):
    logit = autoint_logit(params, batch, cfg, ax)
    y = batch["labels"].astype(jnp.float32)
    return jnp.mean(jnp.maximum(logit, 0) - logit * y
                    + jnp.log1p(jnp.exp(-jnp.abs(logit))))


def make_autoint_train_step(cfg: AutoIntConfig, ax: MeshAxes, opt_cfg):
    from repro.optim import adamw_update
    from functools import partial

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            partial(autoint_loss, cfg=cfg, ax=ax))(params, batch)
        params, opt_state, gnorm = adamw_update(params, grads, opt_state, opt_cfg)
        return params, opt_state, {"loss": loss, "grad_norm": gnorm}

    return train_step


def make_autoint_serve_step(cfg: AutoIntConfig, ax: MeshAxes):
    def serve_step(params, batch):
        return jax.nn.sigmoid(autoint_logit(params, batch, cfg, ax))
    return serve_step


def make_retrieval_step(cfg: AutoIntConfig, ax: MeshAxes, top_k: int = 100):
    """Score one query batch against [n_cand, d_retrieval] item vectors."""

    def retrieval_step(params, batch):
        q = mlp_apply(params["retr_proj"],
                      autoint_embed(params, batch, cfg, ax,
                                    batch_axes=None), 1)          # [B, dR]
        cand = batch["cand_vecs"]                                 # [Nc, dR]
        scores = q @ cand.T                                       # [B, Nc]
        # query batch may be 1 — keep it replicated; shard the candidate axis
        scores = lax.with_sharding_constraint(scores, P(None, ax.model))
        vals, idx = lax.top_k(scores, top_k)
        return vals, idx

    return retrieval_step
