"""Declarative parameter trees.

A model declares its parameters once as a pytree of ``ParamDef``; the same
declaration then yields (a) materialized arrays for training/smoke tests,
(b) ``ShapeDtypeStruct`` stand-ins for the no-allocation dry-run, and
(c) a ``PartitionSpec`` tree for pjit in/out shardings. Keeping all three
views in lock-step is what makes 40 dry-run cells tractable.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: tuple
    pspec: P
    init: str = "normal"       # normal | zeros | ones | embed
    scale: float | None = None  # None -> 1/sqrt(fan_in)
    dtype: jnp.dtype | None = None  # None -> model default


def _is_def(x):
    return isinstance(x, ParamDef)


def materialize(defs, key, default_dtype=jnp.float32):
    leaves, treedef = jax.tree_util.tree_flatten(defs, is_leaf=_is_def)
    keys = jax.random.split(key, len(leaves))
    out = []
    for d, k in zip(leaves, keys):
        dt = d.dtype or default_dtype
        if d.init == "zeros":
            out.append(jnp.zeros(d.shape, dt))
        elif d.init == "ones":
            out.append(jnp.ones(d.shape, dt))
        else:
            fan_in = d.shape[-2] if len(d.shape) >= 2 else d.shape[-1]
            scale = d.scale if d.scale is not None else fan_in ** -0.5
            out.append((jax.random.normal(k, d.shape, jnp.float32) * scale).astype(dt))
    return jax.tree_util.tree_unflatten(treedef, out)


def abstract(defs, default_dtype=jnp.float32):
    return jax.tree_util.tree_map(
        lambda d: jax.ShapeDtypeStruct(d.shape, d.dtype or default_dtype),
        defs, is_leaf=_is_def)


def specs(defs):
    return jax.tree_util.tree_map(lambda d: d.pspec, defs, is_leaf=_is_def)


def n_params(defs) -> int:
    import math
    return sum(math.prod(d.shape) for d in
               jax.tree_util.tree_leaves(defs, is_leaf=_is_def))
