"""GNN zoo: GAT, EGNN, MACE, GraphCast-style encoder-processor-decoder.

Message passing is built on the repo's segment-op substrate (JAX has no
sparse SpMM beyond BCOO): padded edge lists ``(src, dst)`` with sentinel
``N`` for padding, gathers by src, ``jax.ops.segment_sum/max`` scatters by
dst (sentinel rows are dropped by scatter mode="drop" semantics). This is
the same gather→reduce→scatter kernel family as the SSSP relaxation — the
two share the dst-tiled Pallas layout at the kernel level.

Batch dict convention (uniform across archs; configs build the specs):
  node_feat [N, Df] f32      edge_src/edge_dst [E] i32 (N = pad sentinel)
  coords    [N, 3]  f32      (egnn / mace)
  edge_feat [E, De] f32      (graphcast)
  graph_id  [N] i32          (batched small graphs; 0 for full-graph)
  labels    arch-dependent

Sharding: node/edge arrays are 1-D sharded over ALL mesh axes (the GNN
analog of the SSSP 1-D block partition); net params are small and
replicated.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import MeshAxes
from repro.models.params import ParamDef
from repro.models import equivariant as eqv


# --------------------------------------------------------------------------
# segment-op substrate
# --------------------------------------------------------------------------

def seg_sum(data, seg, n):
    return jax.ops.segment_sum(data, seg, num_segments=n)


def seg_max(data, seg, n):
    return jax.ops.segment_max(data, seg, num_segments=n)


def seg_mean(data, seg, n):
    s = seg_sum(data, seg, n)
    cnt = seg_sum(jnp.ones((data.shape[0],) + (1,) * (data.ndim - 1),
                           data.dtype), seg, n)
    return s / jnp.maximum(cnt, 1.0)


def seg_softmax(scores, seg, n):
    """Numerically-stable softmax over edges grouped by destination."""
    mx = seg_max(scores, seg, n)
    mx = jnp.where(jnp.isfinite(mx), mx, 0.0)
    ex = jnp.exp(scores - jnp.take(mx, seg, axis=0, mode="fill", fill_value=0.0))
    den = seg_sum(ex, seg, n)
    return ex / jnp.take(jnp.maximum(den, 1e-9), seg, axis=0, mode="fill",
                         fill_value=1.0)


def gather_nodes(h, idx):
    return jnp.take(h, idx, axis=0, mode="fill", fill_value=0.0)


# --------------------------------------------------------------------------
# tiny MLP helper (ParamDef-declared)
# --------------------------------------------------------------------------

def mlp_defs(dims, *, ln: bool = False):
    d = {}
    for i in range(len(dims) - 1):
        d[f"w{i}"] = ParamDef((dims[i], dims[i + 1]), P(None, None))
        d[f"b{i}"] = ParamDef((dims[i + 1],), P(None), init="zeros")
    if ln:
        d["ln"] = ParamDef((dims[-1],), P(None), init="ones")
    return d


def mlp_apply(p, x, n_layers, act=jax.nn.silu):
    for i in range(n_layers):
        x = x @ p[f"w{i}"] + p[f"b{i}"]
        if i < n_layers - 1:
            x = act(x)
    if "ln" in p:
        mu = jnp.mean(x, axis=-1, keepdims=True)
        var = jnp.var(x, axis=-1, keepdims=True)
        x = (x - mu) * lax.rsqrt(var + 1e-5) * p["ln"]
    return x


# ==========================================================================
# GAT  [arXiv:1710.10903]
# ==========================================================================

@dataclasses.dataclass(frozen=True)
class GatConfig:
    name: str = "gat-cora"
    n_layers: int = 2
    d_hidden: int = 8
    n_heads: int = 8
    d_in: int = 1433
    n_classes: int = 7
    leaky_slope: float = 0.2


def gat_param_defs(cfg: GatConfig, ax: MeshAxes):
    layers = []
    d_in = cfg.d_in
    for i in range(cfg.n_layers):
        last = i == cfg.n_layers - 1
        heads = 1 if last else cfg.n_heads
        d_out = cfg.n_classes if last else cfg.d_hidden
        layers.append(dict(
            w=ParamDef((d_in, heads * d_out), P(None, None)),
            a_src=ParamDef((heads, d_out), P(None, None)),
            a_dst=ParamDef((heads, d_out), P(None, None)),
        ))
        d_in = heads * d_out
    return dict(layers=layers)


def gat_forward(params, batch, cfg: GatConfig, ax: MeshAxes):
    h = batch["node_feat"]
    src, dst = batch["edge_src"], batch["edge_dst"]
    N = h.shape[0]
    for i, lp in enumerate(params["layers"]):
        last = i == cfg.n_layers - 1
        heads = 1 if last else cfg.n_heads
        d_out = cfg.n_classes if last else cfg.d_hidden
        wh = (h @ lp["w"]).reshape(N, heads, d_out)
        s_src = jnp.einsum("nhd,hd->nh", wh, lp["a_src"])
        s_dst = jnp.einsum("nhd,hd->nh", wh, lp["a_dst"])
        e = gather_nodes(s_src, src) + gather_nodes(s_dst, dst)   # [E, H]
        e = jax.nn.leaky_relu(e, cfg.leaky_slope)
        pad = src >= N
        e = jnp.where(pad[:, None], -jnp.inf, e)
        alpha = seg_softmax(e, jnp.where(pad, N, dst), N)         # [E, H]
        msg = alpha[..., None] * gather_nodes(wh, src)            # [E, H, D]
        h = seg_sum(msg, jnp.where(pad, N, dst), N)               # pad -> drop? sentinel==N ok with num_segments=N
        h = h.reshape(N, heads * d_out)
        if not last:
            h = jax.nn.elu(h)
        h = lax.with_sharding_constraint(h, P(ax.all, None))
    return h  # [N, n_classes]


def gat_loss(params, batch, cfg, ax):
    logits = gat_forward(params, batch, cfg, ax)
    labels = batch["labels"]
    mask = labels >= 0
    logz = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, jnp.maximum(labels, 0)[:, None], axis=-1)[:, 0]
    return jnp.sum(jnp.where(mask, logz - ll, 0.0)) / jnp.maximum(mask.sum(), 1)


# ==========================================================================
# EGNN  [arXiv:2102.09844]
# ==========================================================================

@dataclasses.dataclass(frozen=True)
class EgnnConfig:
    name: str = "egnn"
    n_layers: int = 4
    d_hidden: int = 64
    d_in: int = 16


def egnn_param_defs(cfg: EgnnConfig, ax: MeshAxes):
    D = cfg.d_hidden
    layers = [dict(
        phi_e=mlp_defs([2 * D + 1, D, D]),
        phi_x=mlp_defs([D, D, 1]),
        phi_h=mlp_defs([2 * D, D, D]),
    ) for _ in range(cfg.n_layers)]
    return dict(embed=mlp_defs([cfg.d_in, D]), layers=layers,
                readout=mlp_defs([D, D, 1]))


def egnn_forward(params, batch, cfg: EgnnConfig, ax: MeshAxes):
    h = mlp_apply(params["embed"], batch["node_feat"], 1)
    x = batch["coords"]
    src, dst = batch["edge_src"], batch["edge_dst"]
    N = h.shape[0]
    pad = src >= N
    seg = jnp.where(pad, N, dst)
    for lp in params["layers"]:
        xs, xd = gather_nodes(x, src), gather_nodes(x, dst)
        d2 = jnp.sum((xd - xs) ** 2, axis=-1, keepdims=True)
        m = mlp_apply(lp["phi_e"],
                      jnp.concatenate([gather_nodes(h, dst),
                                       gather_nodes(h, src), d2], -1), 2)
        m = jnp.where(pad[:, None], 0.0, m)
        w = mlp_apply(lp["phi_x"], m, 2)                      # [E, 1]
        x = x + seg_mean((xd - xs) * w, seg, N)
        agg = seg_sum(m, seg, N)
        h = h + mlp_apply(lp["phi_h"], jnp.concatenate([h, agg], -1), 2)
        h = lax.with_sharding_constraint(h, P(ax.all, None))
    return h, x


def egnn_loss(params, batch, cfg, ax):
    h, x = egnn_forward(params, batch, cfg, ax)
    pred = mlp_apply(params["readout"], h, 2)[:, 0]
    return jnp.mean((pred - batch["labels"]) ** 2)


# ==========================================================================
# MACE  [arXiv:2206.07697] — l<=2 irreps, correlation order 3
# ==========================================================================

@dataclasses.dataclass(frozen=True)
class MaceConfig:
    name: str = "mace"
    n_layers: int = 2
    d_hidden: int = 128
    l_max: int = 2
    correlation: int = 3
    n_rbf: int = 8
    r_cut: float = 5.0
    n_species: int = 10

    @property
    def ls(self):
        return tuple(range(self.l_max + 1))


def _tp_paths(l_max):
    """Allowed (l1, l2, l3) couplings with all l <= l_max."""
    out = []
    for l1 in range(l_max + 1):
        for l2 in range(l_max + 1):
            for l3 in range(abs(l1 - l2), min(l1 + l2, l_max) + 1):
                out.append((l1, l2, l3))
    return out


def mace_param_defs(cfg: MaceConfig, ax: MeshAxes):
    C = cfg.d_hidden
    paths = _tp_paths(cfg.l_max)
    layers = []
    for _ in range(cfg.n_layers):
        lp = dict(
            radial=mlp_defs([cfg.n_rbf, C, len(paths) * C]),
            # per-l channel mixing after aggregation (A-basis linear)
            mix_a={str(l): ParamDef((C, C), P(None, None)) for l in cfg.ls},
            # product-basis mixing (correlation 2 and 3 contributions)
            mix_b2={str(l): ParamDef((C, C), P(None, None)) for l in cfg.ls},
            mix_b3={str(l): ParamDef((C, C), P(None, None)) for l in cfg.ls},
            update={str(l): ParamDef((C, C), P(None, None)) for l in cfg.ls},
            resid={str(l): ParamDef((C, C), P(None, None)) for l in cfg.ls},
        )
        layers.append(lp)
    return dict(
        embed=ParamDef((cfg.n_species, C), P(None, None), init="embed", scale=1.0),
        layers=layers,
        readout=mlp_defs([C, C, 1]),
    )


def _tensor_product(a, b, l1, l2, l3):
    """Channel-wise CG product: a [N,C,2l1+1] x b [N,C|1,2l2+1] -> [N,C,2l3+1]."""
    cg = jnp.asarray(eqv.real_cg(l1, l2, l3))
    if b.ndim == 2:  # SH without channel dim
        return jnp.einsum("ncx,ny,xyz->ncz", a, b, cg)
    return jnp.einsum("ncx,ncy,xyz->ncz", a, b, cg)


def mace_forward(params, batch, cfg: MaceConfig, ax: MeshAxes):
    src, dst = batch["edge_src"], batch["edge_dst"]
    x = batch["coords"]
    N = x.shape[0]
    C = cfg.d_hidden
    pad = src >= N
    seg = jnp.where(pad, N, dst)
    species = batch["node_feat"][:, 0].astype(jnp.int32)

    h = {l: jnp.zeros((N, C, 2 * l + 1), jnp.float32) for l in cfg.ls}
    h[0] = jnp.take(params["embed"], species, axis=0, mode="clip")[..., None]

    vec = gather_nodes(x, dst) - gather_nodes(x, src)
    r = jnp.sqrt(jnp.sum(vec * vec, -1) + 1e-9)
    sh = eqv.spherical_harmonics(vec)                     # {l2: [E, 2l2+1]}
    rbf = eqv.bessel_rbf(r, cfg.n_rbf, cfg.r_cut)         # [E, n_rbf]
    paths = _tp_paths(cfg.l_max)

    for lp in params["layers"]:
        Rw = mlp_apply(lp["radial"], rbf, 2).reshape(-1, len(paths), C)
        Rw = jnp.where(pad[:, None, None], 0.0, Rw)
        # ---- A-basis: aggregate R * (h_src^l1 x Y^l2 -> l3) per path ------
        A = {l: jnp.zeros((N, C, 2 * l + 1), jnp.float32) for l in cfg.ls}
        for pi, (l1, l2, l3) in enumerate(paths):
            hj = gather_nodes(h[l1], src)                 # [E, C, 2l1+1]
            tp = _tensor_product(hj, sh[l2], l1, l2, l3)  # [E, C, 2l3+1]
            A[l3] = A[l3] + seg_sum(tp * Rw[:, pi, :, None], seg, N)
        A = {l: jnp.einsum("ncm,cd->ndm", A[l], lp["mix_a"][str(l)])
             for l in cfg.ls}
        # ---- B-basis: symmetric products up to correlation 3 --------------
        B = {l: A[l] for l in cfg.ls}
        A2 = {l: jnp.zeros_like(A[l]) for l in cfg.ls}
        for (l1, l2, l3) in paths:
            A2[l3] = A2[l3] + _tensor_product(A[l1], A[l2], l1, l2, l3)
        for l in cfg.ls:
            B[l] = B[l] + jnp.einsum("ncm,cd->ndm", A2[l], lp["mix_b2"][str(l)])
        A3 = {l: jnp.zeros_like(A[l]) for l in cfg.ls}
        for (l1, l2, l3) in paths:
            A3[l3] = A3[l3] + _tensor_product(A2[l1], A[l2], l1, l2, l3)
        for l in cfg.ls:
            B[l] = B[l] + jnp.einsum("ncm,cd->ndm", A3[l], lp["mix_b3"][str(l)])
        # ---- update + residual -------------------------------------------
        h = {l: jnp.einsum("ncm,cd->ndm", B[l], lp["update"][str(l)])
             + jnp.einsum("ncm,cd->ndm", h[l], lp["resid"][str(l)])
             for l in cfg.ls}
        h = {l: lax.with_sharding_constraint(v, P(ax.all, None, None))
             for l, v in h.items()}
    return h


def mace_loss(params, batch, cfg: MaceConfig, ax):
    h = mace_forward(params, batch, cfg, ax)
    site_e = mlp_apply(params["readout"], h[0][..., 0], 2)[:, 0]   # [N]
    G = batch["graph_energy"].shape[0]
    energy = jax.ops.segment_sum(site_e, batch["graph_id"], num_segments=G)
    return jnp.mean((energy - batch["graph_energy"]) ** 2)


# ==========================================================================
# GraphCast-style encoder-processor-decoder  [arXiv:2212.12794]
# ==========================================================================

@dataclasses.dataclass(frozen=True)
class GraphcastConfig:
    name: str = "graphcast"
    n_layers: int = 16
    d_hidden: int = 512
    n_vars: int = 227
    mesh_refinement: int = 6
    d_edge_in: int = 4


def graphcast_param_defs(cfg: GraphcastConfig, ax: MeshAxes):
    D = cfg.d_hidden
    layers = [dict(
        edge_mlp=mlp_defs([3 * D, D, D], ln=True),
        node_mlp=mlp_defs([2 * D, D, D], ln=True),
    ) for _ in range(cfg.n_layers)]
    return dict(
        node_enc=mlp_defs([cfg.n_vars, D, D], ln=True),
        edge_enc=mlp_defs([cfg.d_edge_in, D, D], ln=True),
        layers=layers,
        node_dec=mlp_defs([D, D, cfg.n_vars]),
    )


def graphcast_forward(params, batch, cfg: GraphcastConfig, ax: MeshAxes):
    src, dst = batch["edge_src"], batch["edge_dst"]
    N = batch["node_feat"].shape[0]
    pad = src >= N
    seg = jnp.where(pad, N, dst)
    h = mlp_apply(params["node_enc"], batch["node_feat"], 2)
    e = mlp_apply(params["edge_enc"], batch["edge_feat"], 2)
    for lp in params["layers"]:
        cat = jnp.concatenate(
            [e, gather_nodes(h, src), gather_nodes(h, dst)], axis=-1)
        e = e + mlp_apply(lp["edge_mlp"], cat, 2)
        e = jnp.where(pad[:, None], 0.0, e)
        agg = seg_sum(e, seg, N)
        h = h + mlp_apply(lp["node_mlp"], jnp.concatenate([h, agg], -1), 2)
        h = lax.with_sharding_constraint(h, P(ax.all, None))
    return mlp_apply(params["node_dec"], h, 2)


def graphcast_loss(params, batch, cfg, ax):
    out = graphcast_forward(params, batch, cfg, ax)
    return jnp.mean((out - batch["labels"]) ** 2)


# --------------------------------------------------------------------------
# generic train step
# --------------------------------------------------------------------------

def make_gnn_train_step(loss_f, cfg, ax: MeshAxes, opt_cfg):
    from repro.optim import adamw_update

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(partial(loss_f, cfg=cfg, ax=ax))(
            params, batch)
        params, opt_state, gnorm = adamw_update(params, grads, opt_state, opt_cfg)
        return params, opt_state, {"loss": loss, "grad_norm": gnorm}

    return train_step
