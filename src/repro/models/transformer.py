"""Decoder-only transformer LM family (dense + MoE), GSPMD-sharded.

One implementation covers the five assigned LM archs via config:
GQA/MQA/MHA, RoPE, RMSNorm, optional per-head QK-norm (Qwen3), GeGLU/SwiGLU,
explicit head_dim (Gemma's 256), embedding scaling (Gemma), and a top-k
routed MoE FFN (OLMoE / Qwen3-MoE) with sort-based dispatch (no [T,E,C]
one-hot tensor).

Sharding (MaxText-style fsdp+tensor):
  params  [..., fsdp, tp]  — weights sharded over BOTH data(+pod) and model
  acts    [batch→data, seq, d_model]
  kv cache [L, B→data, Hkv, S→model, Dh] — decode shards the *sequence* over
  the model axis (uniform across archs; works when Hkv < model parallelism,
  the Qwen3/Mistral case; attention contractions over S psum automatically).

Attention impls: "xla" (materialized scores), "chunked" (lax.scan online
softmax — flash-style memory behaviour, lowerable on any backend; the
dry-run default), "pallas" (the real kernel, TPU runtime only).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import MeshAxes
from repro.models.params import ParamDef
from repro.models import moe as moe_mod


@dataclasses.dataclass(frozen=True)
class MoeConfig:
    n_experts: int
    top_k: int
    d_expert: int
    capacity_factor: float = 1.25
    aux_weight: float = 0.01
    norm_topk: bool = True


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int | None = None          # None -> d_model // n_heads
    activation: str = "silu"             # silu (SwiGLU) | gelu (GeGLU)
    moe: MoeConfig | None = None
    moe_impl: str = "shmap"              # shmap (manual EP combine; 2.3x
                                         # less wire than gspmd) | gspmd
    qk_norm: bool = False                # Qwen3
    embed_scale: bool = False            # Gemma: x *= sqrt(d_model)
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    dtype: Any = jnp.bfloat16
    remat: bool = True
    scan_layers: bool = True             # False: unroll (dry-run uses this —
                                         # XLA cost_analysis counts scan
                                         # bodies once, breaking FLOP totals)
    attn_impl: str = "chunked"           # xla | chunked | pallas
    attn_chunk: int = 1024

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def n_params(self) -> int:
        from repro.models.params import n_params
        return n_params(param_defs(self, MeshAxes(data=("data",))))

    def n_active_params(self) -> int:
        """Params touched per token (MoE counts top_k experts only)."""
        total = self.n_params()
        if self.moe is None:
            return total
        e, k = self.moe.n_experts, self.moe.top_k
        expert = 3 * self.d_model * self.moe.d_expert * self.n_layers
        return total - expert * e + expert * k


# --------------------------------------------------------------------------
# parameter declaration
# --------------------------------------------------------------------------

def param_defs(cfg: TransformerConfig, ax: MeshAxes):
    D, H, Hkv, Dh, F, V, L = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                              cfg.hd, cfg.d_ff, cfg.vocab_size, cfg.n_layers)
    fsdp, tp = ax.data, ax.model

    def ld(shape, pspec, **kw):  # layer-stacked param (leading L dim for scan)
        return ParamDef((L, *shape), P(None, *pspec), **kw)

    layer = dict(
        attn_norm=ld((D,), (None,), init="ones"),
        wq=ld((D, H * Dh), (fsdp, tp)),
        wk=ld((D, Hkv * Dh), (fsdp, tp)),
        wv=ld((D, Hkv * Dh), (fsdp, tp)),
        wo=ld((H * Dh, D), (tp, fsdp)),
        mlp_norm=ld((D,), (None,), init="ones"),
    )
    if cfg.qk_norm:
        layer["q_norm"] = ld((Dh,), (None,), init="ones")
        layer["k_norm"] = ld((Dh,), (None,), init="ones")
    if cfg.moe is None:
        layer.update(
            w_gate=ld((D, F), (fsdp, tp)),
            w_up=ld((D, F), (fsdp, tp)),
            w_down=ld((F, D), (tp, fsdp)),
        )
    else:
        E, Fe = cfg.moe.n_experts, cfg.moe.d_expert
        layer.update(
            w_router=ld((D, E), (fsdp, None)),
            w_gate=ld((E, D, Fe), (tp, fsdp, None)),
            w_up=ld((E, D, Fe), (tp, fsdp, None)),
            w_down=ld((E, Fe, D), (tp, None, fsdp)),
        )
    return dict(
        embed=ParamDef((V, D), P(tp, fsdp), init="embed", scale=1.0),
        layers=layer,
        final_norm=ParamDef((D,), P(None), init="ones"),
        unembed=ParamDef((D, V), P(fsdp, tp)),
    )


# --------------------------------------------------------------------------
# building blocks
# --------------------------------------------------------------------------

@partial(jax.custom_vjp, nondiff_argnums=(1,))
def dtype_fence(x, dtype):
    """Identity forward; backward casts the cotangent to ``dtype``.
    Placed on the residual stream at layer boundaries so the backward
    partial-sum all-reduces move bf16, not the f32 the loss path leaks in
    (measured 2x on the dominant collective term — EXPERIMENTS.md §Perf)."""
    return x


def _fence_fwd(x, dtype):
    return x, None


def _fence_bwd(dtype, _, ct):
    return (ct.astype(dtype),)


dtype_fence.defvjp(_fence_fwd, _fence_bwd)


def _use(w, *spec):
    """ZeRO-3-style FSDP weight gather at use-site.

    Weights are STORED sharded over (fsdp=data, tp=model); matmuls must not
    contract over a sharded dimension or GSPMD falls back to all-reducing
    the full-width f32 activation over the data axis (measured: 3.2 GiB x
    2-3/layer on mistral-large — 4.1 TB/step/device; see EXPERIMENTS.md
    §Perf iter 1). Constraining the weight to its use-layout forces the
    ~100x smaller per-layer weight all-gather instead."""
    return lax.with_sharding_constraint(w, P(*spec))


def rmsnorm(x, g, eps):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * g.astype(jnp.float32)).astype(x.dtype)


def rope(x, positions, theta):
    """x: [B, S, H, Dh]; positions: [B, S] int32."""
    Dh = x.shape[-1]
    half = Dh // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq      # [B, S, half]
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def _attn_xla(q, k, v, *, causal, q_offset, scale):
    """q: [B, S, H, Dh]; k/v: [B, Skv, Hkv, Dh] (materialized scores)."""
    B, S, H, Dh = q.shape
    Hkv = k.shape[2]
    g = H // Hkv
    qh = q.reshape(B, S, Hkv, g, Dh)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qh.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        qi = jnp.arange(S)[:, None] + q_offset
        kj = jnp.arange(k.shape[1])[None, :]
        s = jnp.where(qi >= kj, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(B, S, H, Dh).astype(q.dtype)


def _chunk_kv(x, chunk):
    B, Skv, Hkv, Dh = x.shape
    nc = -(-Skv // chunk)
    xp = jnp.pad(x, ((0, 0), (0, nc * chunk - Skv), (0, 0), (0, 0)))
    return xp.reshape(B, nc, chunk, Hkv, Dh).transpose(1, 0, 2, 3, 4), nc


def _attn_fwd_scan(q, k, v, causal, q_offset, scale, chunk):
    B, S, H, Dh = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    g = H // Hkv
    kc, nc = _chunk_kv(k, chunk)
    vc, _ = _chunk_kv(v, chunk)
    qh = q.reshape(B, S, Hkv, g, Dh).astype(jnp.float32)
    qi = jnp.arange(S)[:, None] + q_offset

    def step(carry, xs):
        acc, m, l = carry
        kb, vb, j = xs
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qh, kb.astype(jnp.float32)) * scale
        kj = j * chunk + jnp.arange(chunk)[None, :]
        valid = kj < Skv
        if causal:
            valid = valid & (qi >= kj)
        s = jnp.where(valid[None, None, None], s, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - jnp.where(jnp.isfinite(m_new), m_new, 0.0)[..., None])
        p = jnp.where(valid[None, None, None], p, 0.0)
        alpha = jnp.where(jnp.isfinite(m), jnp.exp(m - m_new), 0.0)
        l = l * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum("bhgqk,bkhd->bhgqd", p,
                                                  vb.astype(jnp.float32))
        return (acc, m_new, l), None

    acc0 = jnp.zeros((B, Hkv, g, S, Dh), jnp.float32)
    m0 = jnp.full((B, Hkv, g, S), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, Hkv, g, S), jnp.float32)
    (acc, m, l), _ = lax.scan(step, (acc0, m0, l0), (kc, vc, jnp.arange(nc)))
    l_safe = jnp.where(l > 0, l, 1.0)
    out = acc / l_safe[..., None]                       # [B, Hkv, g, S, Dh]
    lse = jnp.where(jnp.isfinite(m), m + jnp.log(l_safe), -jnp.inf)
    return out, lse


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _attn_chunked(q, k, v, causal, q_offset, scale, chunk):
    """Flash-style attention in pure XLA with a FLASH BACKWARD (custom_vjp):
    the naive VJP of the online-softmax scan stores the f32 accumulator at
    every chunk step (~GiB/layer at 4k; see EXPERIMENTS.md §Perf) — the
    custom backward recomputes probabilities chunk-by-chunk from (out, lse)
    instead, FlashAttention-2 style."""
    out, _ = _attn_fwd_scan(q, k, v, causal, q_offset, scale, chunk)
    B, S, H, Dh = q.shape
    return out.transpose(0, 3, 1, 2, 4).reshape(B, S, H, Dh).astype(q.dtype)


def _attn_chunked_fwd(q, k, v, causal, q_offset, scale, chunk):
    out, lse = _attn_fwd_scan(q, k, v, causal, q_offset, scale, chunk)
    B, S, H, Dh = q.shape
    o = out.transpose(0, 3, 1, 2, 4).reshape(B, S, H, Dh).astype(q.dtype)
    return o, (q, k, v, out.astype(q.dtype), lse)


def _attn_chunked_bwd(causal, q_offset, scale, chunk, res, do):
    q, k, v, out, lse = res
    B, S, H, Dh = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    g = H // Hkv
    qh = q.reshape(B, S, Hkv, g, Dh).astype(jnp.float32)
    doh = do.reshape(B, S, Hkv, g, Dh).transpose(0, 2, 3, 1, 4).astype(jnp.float32)
    out32 = out.astype(jnp.float32)                     # [B, Hkv, g, S, Dh]
    delta = jnp.sum(doh * out32, axis=-1)               # [B, Hkv, g, S]
    kc, nc = _chunk_kv(k, chunk)
    vc, _ = _chunk_kv(v, chunk)
    qi = jnp.arange(S)[:, None] + q_offset
    lse_safe = jnp.where(jnp.isfinite(lse), lse, 0.0)

    def step(dq, xs):
        kb, vb, j = xs
        kb32, vb32 = kb.astype(jnp.float32), vb.astype(jnp.float32)
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qh, kb32) * scale
        kj = j * chunk + jnp.arange(chunk)[None, :]
        valid = kj < Skv
        if causal:
            valid = valid & (qi >= kj)
        p = jnp.where(valid[None, None, None],
                      jnp.exp(s - lse_safe[..., None]), 0.0)
        dv = jnp.einsum("bhgqk,bhgqd->bkhd", p, doh)
        dp = jnp.einsum("bhgqd,bkhd->bhgqk", doh, vb32)
        ds = p * (dp - delta[..., None]) * scale
        dq = dq + jnp.einsum("bhgqk,bkhd->bqhgd", ds, kb32)
        dk = jnp.einsum("bhgqk,bqhgd->bkhd", ds, qh)
        return dq, (dk, dv)

    dq0 = jnp.zeros((B, S, Hkv, g, Dh), jnp.float32)
    dq, (dkc, dvc) = lax.scan(step, dq0, (kc, vc, jnp.arange(nc)))
    dk = dkc.transpose(1, 0, 2, 3, 4).reshape(B, nc * chunk, Hkv, Dh)[:, :Skv]
    dv = dvc.transpose(1, 0, 2, 3, 4).reshape(B, nc * chunk, Hkv, Dh)[:, :Skv]
    return (dq.reshape(B, S, H, Dh).astype(q.dtype), dk.astype(k.dtype),
            dv.astype(v.dtype))


_attn_chunked.defvjp(_attn_chunked_fwd, _attn_chunked_bwd)


def attention(q, k, v, cfg: TransformerConfig, *, causal=True, q_offset=0):
    scale = cfg.hd ** -0.5
    if cfg.attn_impl == "pallas":
        from repro.kernels.flash_attention import flash_attention
        o = flash_attention(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                            v.transpose(0, 2, 1, 3), causal=causal,
                            q_offset=q_offset, interpret=False)
        return o.transpose(0, 2, 1, 3)
    if cfg.attn_impl == "chunked" and q.shape[1] > 1:
        return _attn_chunked(q, k, v, causal, q_offset, scale, cfg.attn_chunk)
    return _attn_xla(q, k, v, causal=causal, q_offset=q_offset, scale=scale)


def _ffn_dense(x, lp, cfg, ax):
    tp = ax.model
    act = jax.nn.silu if cfg.activation == "silu" else partial(jax.nn.gelu, approximate=True)
    h = act(x @ _use(lp["w_gate"], None, tp)) * (x @ _use(lp["w_up"], None, tp))
    return h @ _use(lp["w_down"], tp, None)


def _layer(x, lp, cfg: TransformerConfig, ax: MeshAxes, positions, cache=None,
           cache_pos=None):
    """One transformer block. x: [B, S, D]. Returns (x', new_cache_slice, aux)."""
    B, S, D = x.shape
    H, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.hd

    tp = ax.model
    h = rmsnorm(x, lp["attn_norm"], cfg.norm_eps)
    q = (h @ _use(lp["wq"], None, tp)).reshape(B, S, H, Dh)
    k = (h @ _use(lp["wk"], None, tp)).reshape(B, S, Hkv, Dh)
    v = (h @ _use(lp["wv"], None, tp)).reshape(B, S, Hkv, Dh)
    if cfg.qk_norm:
        q = rmsnorm(q, lp["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, lp["k_norm"], cfg.norm_eps)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)

    if cache is None:
        o = attention(q, k, v, cfg, causal=True)
        new_cache = (k, v)
    else:
        ck, cv = cache           # [B, Skv, Hkv, Dh], decode: S == 1
        ck = lax.dynamic_update_slice(ck, k, (0, cache_pos, 0, 0))
        cv = lax.dynamic_update_slice(cv, v, (0, cache_pos, 0, 0))
        ck = lax.with_sharding_constraint(ck, P(ax.data, ax.model, None, None))
        cv = lax.with_sharding_constraint(cv, P(ax.data, ax.model, None, None))
        o = _attn_xla(q, ck, cv, causal=True, q_offset=cache_pos,
                      scale=cfg.hd ** -0.5)
        new_cache = (ck, cv)
    x = x + (o.reshape(B, S, H * Dh) @ _use(lp["wo"], tp, None))

    h = rmsnorm(x, lp["mlp_norm"], cfg.norm_eps)
    if cfg.moe is None:
        y, aux = _ffn_dense(h, lp, cfg, ax), jnp.float32(0)
    else:
        y, aux = moe_mod.moe_ffn(h, lp, cfg.moe, cfg.activation, ax,
                                 impl=cfg.moe_impl)
    x = x + y
    x = dtype_fence(x, cfg.dtype)
    x = lax.with_sharding_constraint(x, P(ax.data, None, None))
    return x, new_cache, aux


# --------------------------------------------------------------------------
# full model
# --------------------------------------------------------------------------

def forward(params, tokens, cfg: TransformerConfig, ax: MeshAxes,
            caches=None, cache_pos=None):
    """tokens: [B, S]. caches: None | (k:[L,B,Skv,Hkv,Dh], v). Returns
    (logits_f32 [B, S, V], new_caches, aux_loss)."""
    B, S = tokens.shape
    embed = lax.with_sharding_constraint(params["embed"], P(ax.model, None))
    x = jnp.take(embed, tokens, axis=0).astype(cfg.dtype)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, cfg.dtype)
    x = lax.with_sharding_constraint(x, P(ax.data, None, None))
    if cache_pos is None:
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    else:
        positions = jnp.broadcast_to(cache_pos + jnp.arange(S)[None], (B, S))

    def body(carry, xs):
        x, aux = carry
        if caches is None:
            lp = xs
            x, kv, a = _layer(x, lp, cfg, ax, positions)
        else:
            lp, ck, cv = xs
            x, kv, a = _layer(x, lp, cfg, ax, positions, cache=(ck, cv),
                              cache_pos=cache_pos)
        return (x, aux + a), kv

    layer_fn = jax.checkpoint(
        body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    ) if cfg.remat else body

    xs = params["layers"] if caches is None else (params["layers"], *caches)
    if cfg.scan_layers:
        (x, aux), kvs = lax.scan(layer_fn, (x, jnp.float32(0)), xs)
    else:  # unrolled: accurate cost_analysis; same stacked param layout
        carry = (x, jnp.float32(0))
        kv_list = []
        for i in range(cfg.n_layers):
            xs_i = jax.tree_util.tree_map(lambda t: t[i], xs)
            carry, kv = layer_fn(carry, xs_i)
            kv_list.append(kv)
        (x, aux) = carry
        kvs = jax.tree_util.tree_map(lambda *ts: jnp.stack(ts), *kv_list)

    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    unembed = lax.with_sharding_constraint(params["unembed"],
                                           P(None, ax.model))
    logits = (x.astype(jnp.float32) @ unembed.astype(jnp.float32))
    logits = lax.with_sharding_constraint(logits, P(ax.data, None, ax.model))
    return logits, kvs, aux


def softmax_xent(logits, labels):
    logz = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - ll)


# --------------------------------------------------------------------------
# step functions (what the launcher jits / the dry-run lowers)
# --------------------------------------------------------------------------

def loss_fn(params, batch, cfg, ax):
    logits, _, aux = forward(params, batch["tokens"], cfg, ax)
    loss = softmax_xent(logits, batch["labels"])
    return loss + (cfg.moe.aux_weight * aux / cfg.n_layers if cfg.moe else 0.0)


def make_train_step(cfg: TransformerConfig, ax: MeshAxes, opt_cfg,
                    microbatches: int = 1):
    """microbatches > 1: gradient accumulation over batch slices — bounds
    activation memory to 1/M of the full step (the straggler-mitigation /
    HBM-fit lever for the >=100B train cells; EXPERIMENTS.md §Perf)."""
    from repro.optim import adamw_update

    grad_fn = jax.value_and_grad(partial(loss_fn, cfg=cfg, ax=ax))

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            loss, grads = grad_fn(params, batch)
        else:
            M = microbatches

            def slice_mb(t, i):
                mb = t.shape[0] // M
                return lax.dynamic_slice_in_dim(t, i * mb, mb, axis=0)

            def body(carry, i):
                gacc, lacc = carry
                mb = jax.tree_util.tree_map(lambda t: slice_mb(t, i), batch)
                loss, grads = grad_fn(params, mb)
                gacc = jax.tree_util.tree_map(
                    lambda a, g: a + g.astype(jnp.float32), gacc, grads)
                return (gacc, lacc + loss), None

            gz = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gacc, lsum), _ = lax.scan(body, (gz, jnp.float32(0)),
                                       jnp.arange(M))
            grads = jax.tree_util.tree_map(lambda g: g / M, gacc)
            loss = lsum / M
        params, opt_state, gnorm = adamw_update(params, grads, opt_state, opt_cfg)
        return params, opt_state, {"loss": loss, "grad_norm": gnorm}

    return train_step


def make_prefill_step(cfg: TransformerConfig, ax: MeshAxes):
    def prefill_step(params, batch):
        logits, kvs, _ = forward(params, batch["tokens"], cfg, ax)
        kvs = jax.tree_util.tree_map(
            lambda t: lax.with_sharding_constraint(
                t, P(None, ax.data, ax.model, None, None)), kvs)
        return logits[:, -1], kvs

    return prefill_step


def make_serve_step(cfg: TransformerConfig, ax: MeshAxes):
    """One decode step: new token + KV cache of seq_len."""

    def serve_step(params, token, caches, pos):
        logits, new_caches, _ = forward(params, token, cfg, ax,
                                        caches=caches, cache_pos=pos)
        return logits[:, -1], new_caches

    return serve_step
