"""Top-k routed MoE FFN with hierarchical sort-based dispatch.

Two-level structure keyed to the mesh (the beyond-GShard design this repo
ships as the baseline after profiling the naive global-argsort dispatch at
565 GiB temp/device — see EXPERIMENTS.md §Perf):

  1. tokens are viewed as [G, Tg, D] where G = number of data shards
     (static); the argsort, capacity masking, and scatter into expert
     buffers are *per-group*, i.e. local to each data shard — no global
     sort, no cross-shard scatter;
  2. expert buffers [G, E, Cg, D] are sharded (G -> data, E -> model):
     the expert GEMMs are fully local (weights are E-sharded over model);
  3. the only communication is the combine: gathering each token's expert
     outputs from E-sharded buffers lowers to one all-reduce over the
     model axis (GSPMD inserts it) — the EP exchange, structurally the
     same per-destination bucket pattern as the SSSP boundary exchange.

Per-group capacity Cg = ceil(Tg·k/E · capacity_factor): group-local
capacity drops differ slightly from global-capacity semantics (documented;
standard in EP implementations).

Aux loss: Switch-style load balancing over global router stats.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro import compat


def _routing_group(topi_g, E: int, k: int, Cg: int):
    """Index-level routing for one group — int32 arrays only, no D-wide
    tensors. topi_g: [Tg, k]. Returns:
      slot_token [E*Cg]: source token of each expert buffer slot (Tg = empty)
      pos [Tg, k], keep [Tg, k]: each assignment's capacity slot / survival
    """
    Tg = topi_g.shape[0]
    flat_e = topi_g.reshape(Tg * k)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    token_of = (order // k).astype(jnp.int32)
    starts = jnp.searchsorted(sorted_e, jnp.arange(E))
    pos_sorted = (jnp.arange(Tg * k) - starts[sorted_e]).astype(jnp.int32)
    kept_sorted = pos_sorted < Cg
    # inverse map: expert-buffer slot -> token (gather-based dispatch)
    slot_of = jnp.where(kept_sorted, sorted_e * Cg + pos_sorted, E * Cg)
    slot_token = jnp.full((E * Cg,), Tg, jnp.int32).at[slot_of].set(
        token_of, mode="drop")
    # forward map back to (token, k) layout
    pos = jnp.zeros((Tg * k,), jnp.int32).at[order].set(pos_sorted)
    pos = pos.reshape(Tg, k)
    keep = pos < Cg
    return slot_token, pos, keep


def _dispatch_group(xg, slot_token, E: int, Cg: int):
    """ONE D-wide gather builds the expert buffers (backward = one
    scatter-add); empty slots read a zero row."""
    xz = jnp.concatenate([xg, jnp.zeros((1, xg.shape[1]), xg.dtype)])
    return xz[slot_token].reshape(E, Cg, xg.shape[1])


def _combine_group(out_buf, topi_g, pos, keep, topv_g, k: int):
    """Single gather of all (token, k) slots + weighted sum.

    The k-contraction is written as elementwise-mul + reduce (NOT einsum):
    the gather from the expert-sharded buffer yields a *partial* tensor,
    and GSPMD defers partial-sum resolution through elementwise ops and
    reductions but not through dot_general — with einsum the all-reduce
    moved the full [Tg, k, D] (8 GiB f32/layer on qwen3); with mul+sum it
    moves [Tg, D] after the k-reduction (8x less; §Perf iter 4)."""
    E, Cg, D = out_buf.shape
    flat = out_buf.reshape(E * Cg, D)
    flat = jnp.concatenate([flat, jnp.zeros((1, D), flat.dtype)])
    idx = jnp.where(keep, topi_g * Cg + pos, E * Cg)     # [Tg, k]
    got = flat[idx]                                      # [Tg, k, D] partial
    w = jnp.where(keep, topv_g, 0.0).astype(out_buf.dtype)
    return jnp.sum(got * w[..., None], axis=1)


def _expert_block_shmap(xg, slot_token, topi_g, pos, keep, topv_g,
                        w_gate, w_up, w_down, activation: str, ax, E: int,
                        k: int, Cg: int):
    """Expert compute + combine under manual collectives (shard_map).

    GSPMD resolves the combine's gather from E-sharded buffers by
    all-reducing the full gathered tensor (§Perf iter 4, refuted path).
    Manually: tokens are replicated within a data row, each model shard
    builds buffers and runs FFN for ITS experts only (zero-comm dispatch),
    computes its partial combine [Tg, D], and ONE psum over the model axis
    finishes the job — the minimal EP exchange for replicated-token MoE.
    """
    import jax
    from jax import lax as _lax

    act = jax.nn.silu if activation == "silu" else partial(jax.nn.gelu, approximate=True)
    model_ax = ax.model

    def body(xg_l, slot_l, topi_l, pos_l, keep_l, topv_l, wg_l, wu_l, wd_l):
        # strip leading G/E dims that shard_map leaves as local slices
        x_l = xg_l[0]                       # [Tg, D]
        sl = slot_l[0]                      # [E_loc, Cg]
        ti, po, ke, tv = topi_l[0], pos_l[0], keep_l[0], topv_l[0]
        E_loc = wg_l.shape[0]
        e0 = _lax.axis_index(model_ax) * E_loc

        xz = jnp.concatenate([x_l, jnp.zeros((1, x_l.shape[1]), x_l.dtype)])
        buf = xz[sl.reshape(-1)].reshape(E_loc, Cg, x_l.shape[1])
        g = jnp.einsum("ecd,edf->ecf", buf, wg_l)
        u = jnp.einsum("ecd,edf->ecf", buf, wu_l)
        out = jnp.einsum("ecf,efd->ecd", act(g) * u, wd_l)  # [E_loc, Cg, D]

        e_rel = ti - e0
        mine = ke & (e_rel >= 0) & (e_rel < E_loc)
        flat = out.reshape(E_loc * Cg, -1)
        flat = jnp.concatenate([flat, jnp.zeros((1, flat.shape[1]), flat.dtype)])
        idx = jnp.where(mine, e_rel * Cg + po, E_loc * Cg)
        got = flat[idx]                                      # [Tg, k, D]
        w = jnp.where(mine, tv, 0.0).astype(out.dtype)
        y_part = jnp.sum(got * w[..., None], axis=1)         # [Tg, D]
        y = _lax.psum(y_part, model_ax)                      # THE EP combine
        return y[None]                                       # restore G dim

    P_ = P
    specs = dict(
        xg=P_(ax.data, None, None),
        slot=P_(ax.data, ax.model, None),
        tok=P_(ax.data, None, None),
        w=P_(ax.model, None, None),
        out=P_(ax.data, None, None),
    )
    return compat.shard_map(
        body,
        in_specs=(specs["xg"], specs["slot"], specs["tok"], specs["tok"],
                  specs["tok"], specs["tok"], specs["w"], specs["w"],
                  specs["w"]),
        out_specs=specs["out"],
        check_vma=False,
    )(xg, slot_token.reshape(xg.shape[0], E, Cg), topi_g, pos, keep, topv_g,
      w_gate, w_up, w_down)


def moe_ffn(x, lp, moe_cfg, activation: str, ax, impl: str = "gspmd"):
    """x: [B, S, D]. lp: w_router [D,E], w_gate/w_up [E,D,F], w_down [E,F,D].
    Returns (y [B, S, D], aux_loss scalar). impl: gspmd | shmap."""
    B, S, D = x.shape
    E, k = moe_cfg.n_experts, moe_cfg.top_k
    T = B * S
    G = max(int(ax.data_shards), 1)
    if impl == "shmap":
        # shard_map body assumes exactly one token group per data shard
        assert T % G == 0, (T, G)
    else:
        while T % G:                               # smoke meshes: G=1 fallback
            G //= 2
    Tg = T // G
    Cg = max(int(Tg * k / E * moe_cfg.capacity_factor), 1)

    xf = x.reshape(T, D)
    logits = (xf @ lp["w_router"].astype(x.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    topv, topi = lax.top_k(probs, k)
    if moe_cfg.norm_topk:
        topv = topv / jnp.sum(topv, axis=-1, keepdims=True)

    xg = xf.reshape(G, Tg, D)
    xg = lax.with_sharding_constraint(xg, P(ax.data, None, None))
    topi_g = topi.reshape(G, Tg, k)
    topv_g = topv.reshape(G, Tg, k)

    slot_token, pos, keep = jax.vmap(
        partial(_routing_group, E=E, k=k, Cg=Cg))(topi_g)

    # ZeRO-3 weight gather at use: keep E sharded (EP over model), gather the
    # fsdp-sharded d_model dim — otherwise GSPMD all-reduces the activation
    w_gate = lax.with_sharding_constraint(lp["w_gate"], P(ax.model, None, None))
    w_up = lax.with_sharding_constraint(lp["w_up"], P(ax.model, None, None))
    w_down = lax.with_sharding_constraint(lp["w_down"], P(ax.model, None, None))

    if impl == "shmap":
        y = _expert_block_shmap(xg, slot_token, topi_g, pos, keep, topv_g,
                                w_gate, w_up, w_down, activation, ax, E, k, Cg)
        y = y.reshape(B, S, D)
    else:
        act = jax.nn.silu if activation == "silu" else partial(jax.nn.gelu, approximate=True)
        buf = jax.vmap(partial(_dispatch_group, E=E, Cg=Cg))(xg, slot_token)
        buf = lax.with_sharding_constraint(buf, P(ax.data, ax.model, None, None))
        g = jnp.einsum("gecd,edf->gecf", buf, w_gate)
        u = jnp.einsum("gecd,edf->gecf", buf, w_up)
        h = act(g) * u
        out = jnp.einsum("gecf,efd->gecd", h, w_down)
        out = lax.with_sharding_constraint(out, P(ax.data, ax.model, None, None))
        y = jax.vmap(partial(_combine_group, k=k))(out, topi_g, pos, keep,
                                                   topv_g)
        y = lax.with_sharding_constraint(y.reshape(B, S, D),
                                         P(ax.data, None, None))

    frac = jnp.mean(jax.nn.one_hot(topi[:, 0], E, dtype=jnp.float32), axis=0)
    prob = jnp.mean(probs, axis=0)
    aux = jnp.sum(frac * prob) * E
    return y, aux
