"""Minimal real-spherical-harmonic / Clebsch-Gordan machinery for MACE.

Supports l <= L_MAX (default 2). CG coefficients are built numerically at
import time (host, numpy): complex CG via the Racah formula, transformed to
the real basis with the standard complex->real unitary U_l. Everything the
model uses at runtime is a dense einsum against these precomputed tables —
TPU-friendly (the O(L^6) naive contraction is fine at l<=2; eSCN-style
tricks only pay at high L).
"""
from __future__ import annotations

from functools import lru_cache
from math import factorial, sqrt

import numpy as np
import jax.numpy as jnp

L_MAX = 2


def _cg_complex(j1, j2, j3, m1, m2, m3):
    """Clebsch-Gordan <j1 m1 j2 m2 | j3 m3> (Racah formula)."""
    if m3 != m1 + m2:
        return 0.0
    if not (abs(j1 - j2) <= j3 <= j1 + j2):
        return 0.0
    f = factorial
    pre = sqrt((2 * j3 + 1) * f(j3 + j1 - j2) * f(j3 - j1 + j2) * f(j1 + j2 - j3)
               / f(j1 + j2 + j3 + 1))
    pre *= sqrt(f(j3 + m3) * f(j3 - m3) * f(j1 - m1) * f(j1 + m1)
                * f(j2 - m2) * f(j2 + m2))
    s = 0.0
    for k in range(0, j1 + j2 - j3 + 1):
        denoms = [k, j1 + j2 - j3 - k, j1 - m1 - k, j2 + m2 - k,
                  j3 - j2 + m1 + k, j3 - j1 - m2 + k]
        if any(d < 0 for d in denoms):
            continue
        s += (-1) ** k / np.prod([float(f(d)) for d in denoms])
    return pre * s


def _real_to_complex_u(l):
    """U[m_complex, m_real] with real-SH convention (m<0 sin, m>0 cos)."""
    dim = 2 * l + 1
    U = np.zeros((dim, dim), complex)
    for m in range(-l, l + 1):
        i = m + l
        if m < 0:
            U[i, m + l] = 1j / sqrt(2)
            U[i, -m + l] = -1j / sqrt(2) * (-1) ** m
        elif m == 0:
            U[i, l] = 1.0
        else:
            U[i, -m + l] = 1 / sqrt(2)
            U[i, m + l] = 1 / sqrt(2) * (-1) ** m
    return U


@lru_cache(maxsize=None)
def real_cg(l1: int, l2: int, l3: int) -> np.ndarray:
    """Real-basis coupling coefficients C[m1, m2, m3] (may be complex-phase
    free by construction for allowed (l1,l2,l3); imaginary parts cancel)."""
    C = np.zeros((2 * l1 + 1, 2 * l2 + 1, 2 * l3 + 1))
    Cc = np.zeros((2 * l1 + 1, 2 * l2 + 1, 2 * l3 + 1), complex)
    for m1 in range(-l1, l1 + 1):
        for m2 in range(-l2, l2 + 1):
            m3 = m1 + m2
            if -l3 <= m3 <= l3:
                Cc[m1 + l1, m2 + l2, m3 + l3] = _cg_complex(l1, l2, l3, m1, m2, m3)
    U1 = _real_to_complex_u(l1)
    U2 = _real_to_complex_u(l2)
    U3 = _real_to_complex_u(l3)
    out = np.einsum("abc,ax,by,cz->xyz", Cc, U1, U2, np.conj(U3))
    # a global phase may remain; rotate it away and keep the real part
    mag = np.abs(out).max()
    if mag > 1e-12:
        phase = out.flat[np.argmax(np.abs(out))]
        out = out * np.conj(phase / abs(phase))
    C = np.real(out)
    return C.astype(np.float32)


def spherical_harmonics(vec, eps: float = 1e-9):
    """Real SH l=0..2 of unit(vec). vec: [..., 3]. Returns dict {l: [..., 2l+1]}.

    Normalization: Racah (Y_00 = 1), consistent across l for CG coupling."""
    r = jnp.sqrt(jnp.sum(vec * vec, axis=-1, keepdims=True) + eps)
    u = vec / r
    x, y, z = u[..., 0], u[..., 1], u[..., 2]
    y0 = jnp.ones_like(x)[..., None]
    y1 = jnp.stack([y, z, x], axis=-1)  # (m=-1, 0, 1) real convention
    s3 = sqrt(3.0)
    y2 = jnp.stack([
        s3 * x * y,
        s3 * y * z,
        0.5 * (3 * z * z - 1.0),
        s3 * x * z,
        0.5 * s3 * (x * x - y * y),
    ], axis=-1)
    return {0: y0, 1: y1, 2: y2}


def bessel_rbf(r, n_rbf: int, r_cut: float):
    """Bessel radial basis with polynomial cutoff (MACE/NequIP standard)."""
    r = r[..., None]
    n = jnp.arange(1, n_rbf + 1, dtype=jnp.float32)
    rb = jnp.sqrt(2.0 / r_cut) * jnp.sin(n * jnp.pi * r / r_cut) / (r + 1e-9)
    x = jnp.clip(r / r_cut, 0.0, 1.0)
    p = 6  # polynomial cutoff order
    fcut = 1 - ((p + 1) * (p + 2) / 2) * x**p + p * (p + 2) * x**(p + 1) \
        - (p * (p + 1) / 2) * x**(p + 2)
    return rb * fcut
