"""MACE [arXiv:2206.07697; paper] — 2L d_hidden=128, l_max=2,
correlation order 3, n_rbf=8, E(3) higher-order message passing."""
from repro.models.gnn import MaceConfig

CONFIG = MaceConfig(name="mace", n_layers=2, d_hidden=128, l_max=2,
                    correlation=3, n_rbf=8)
SMOKE = MaceConfig(name="mace-smoke", n_layers=1, d_hidden=8, l_max=2,
                   correlation=3, n_rbf=4)
