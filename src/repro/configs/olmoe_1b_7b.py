"""OLMoE-1B-7B [arXiv:2409.02060; hf] — 16L d2048 16H (GQA kv=16) MoE 64e top-8,
d_ff(expert)=1024, vocab 50304. head_dim = 2048/16 = 128."""
from repro.models.transformer import TransformerConfig, MoeConfig

CONFIG = TransformerConfig(
    name="olmoe-1b-7b", n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1024, vocab_size=50304,
    moe=MoeConfig(n_experts=64, top_k=8, d_expert=1024),
    activation="silu", qk_norm=True,  # OLMoE uses QK-norm
)

SMOKE = TransformerConfig(
    name="olmoe-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=64, vocab_size=128, moe=MoeConfig(n_experts=4, top_k=2, d_expert=64),
    activation="silu", qk_norm=True, dtype="float32", attn_chunk=16,
)
