from repro.configs.registry import ARCHS, SHAPES, build_cell, list_cells
