"""EGNN [arXiv:2102.09844; paper] — 4L d_hidden=64, E(n)-equivariant."""
from repro.models.gnn import EgnnConfig

CONFIG = EgnnConfig(name="egnn", n_layers=4, d_hidden=64)
SMOKE = EgnnConfig(name="egnn-smoke", n_layers=2, d_hidden=16, d_in=8)
