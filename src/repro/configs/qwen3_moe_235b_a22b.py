"""Qwen3-MoE-235B-A22B [hf] — 94L d4096 64H (GQA kv=4) MoE 128e top-8,
d_ff(expert)=1536, vocab 151936. head_dim=128 (Qwen3 public config; spec
omits it), QK-norm per head."""
from repro.models.transformer import TransformerConfig, MoeConfig

CONFIG = TransformerConfig(
    name="qwen3-moe-235b-a22b", n_layers=94, d_model=4096, n_heads=64,
    n_kv_heads=4, head_dim=128, d_ff=1536, vocab_size=151936,
    moe=MoeConfig(n_experts=128, top_k=8, d_expert=1536),
    activation="silu", qk_norm=True, rope_theta=1_000_000.0,
)

SMOKE = TransformerConfig(
    name="qwen3-moe-smoke", n_layers=2, d_model=64, n_heads=8, n_kv_heads=2,
    head_dim=16, d_ff=96, vocab_size=128,
    moe=MoeConfig(n_experts=8, top_k=2, d_expert=96),
    activation="silu", qk_norm=True, dtype="float32", attn_chunk=16,
)
