"""The paper's own evaluation graphs (§IV.A) as dry-run cells.

Full-scale graphs exist only as ShapeDtypeStruct workload models for the
dry-run; the executable benchmarks use generated graphs of reduced scale
(benchmarks/sssp_bench.py). Cut fractions encode partition locality:
road networks partition well under 1-D blocks, social/synthetic graphs
do not (~random cut). Skew=4 models hot destination shards.
"""
import dataclasses


@dataclasses.dataclass(frozen=True)
class SsspGraphSpec:
    name: str
    n_vertices: int
    n_edges: int
    cut_fraction: float    # share of edges crossing partitions
    tri_per_edge: float    # triangle candidates per local edge
    skew: float = 4.0      # bucket-capacity skew multiplier

    def shard_shapes(self, n_parts: int):
        block = -(-self.n_vertices // n_parts)
        e_shard = -(-self.n_edges // n_parts)
        e_loc = max(int(e_shard * (1 - self.cut_fraction) * 1.15), 8)
        e_cut = max(int(e_shard * self.cut_fraction * 1.15), 8)
        S = max(min(e_cut, int(e_cut * 0.8)), 8)          # unique boundary pairs
        C = max(int(S / max(n_parts - 1, 1) * self.skew), 8)
        T = max(int(e_loc * self.tri_per_edge), 8)
        return dict(block=block, e_loc=e_loc, e_cut=e_cut, S=S, C=C, T=T)


GRAPHS = {
    "graph1": SsspGraphSpec("graph1", 391_529, 873_775, 0.90, 0.5),
    "graph2": SsspGraphSpec("graph2", 23_947_347, 58_333_344, 0.05, 0.3),
    "graph3": SsspGraphSpec("graph3", 3_072_441, 117_185_083, 0.90, 2.0),
    "graph4": SsspGraphSpec("graph4", 41_700_000, 1_470_000_000, 0.95, 1.0),
}
