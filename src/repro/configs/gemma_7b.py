"""Gemma-7B [arXiv:2403.08295; hf] — 28L d3072 16H (kv=16, i.e. MHA at 7B;
MQA only on 2B) d_ff 24576, vocab 256000, GeGLU, head_dim=256 (explicit),
embeddings scaled by sqrt(d_model)."""
from repro.models.transformer import TransformerConfig

CONFIG = TransformerConfig(
    name="gemma-7b", n_layers=28, d_model=3072, n_heads=16, n_kv_heads=16,
    head_dim=256, d_ff=24576, vocab_size=256000, activation="gelu",
    embed_scale=True,
)

SMOKE = TransformerConfig(
    name="gemma-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    head_dim=32, d_ff=128, vocab_size=256, activation="gelu",
    embed_scale=True, dtype="float32", attn_chunk=16,
)
