"""AutoInt [arXiv:1810.11921; paper] — 39 sparse fields, embed_dim 16,
3 attention layers, 2 heads, d_attn=32. vocab_per_field=1e6 (Criteo-scale;
the spec leaves vocab open — documented in DESIGN.md)."""
from repro.models.autoint import AutoIntConfig

CONFIG = AutoIntConfig(name="autoint", n_sparse=39, vocab_per_field=1_000_000,
                       embed_dim=16, n_attn_layers=3, n_heads=2, d_attn=32)
SMOKE = AutoIntConfig(name="autoint-smoke", n_sparse=5, vocab_per_field=64,
                      embed_dim=8, n_attn_layers=2, n_heads=2, d_attn=8)
