"""Mistral-Large-123B [hf:mistralai/Mistral-Large-Instruct-2407; unverified]
— 88L d12288 96H (GQA kv=8) d_ff 28672 vocab 32768. head_dim=128."""
from repro.models.transformer import TransformerConfig

CONFIG = TransformerConfig(
    name="mistral-large-123b", n_layers=88, d_model=12288, n_heads=96,
    n_kv_heads=8, d_ff=28672, vocab_size=32768, activation="silu",
)

SMOKE = TransformerConfig(
    name="mistral-large-smoke", n_layers=2, d_model=96, n_heads=6,
    n_kv_heads=2, d_ff=160, vocab_size=128, activation="silu",
    dtype="float32", attn_chunk=16,
)
