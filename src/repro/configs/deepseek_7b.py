"""DeepSeek-7B [arXiv:2401.02954; hf] — llama-arch: 30L d4096 32H (MHA kv=32)
d_ff 11008 vocab 102400, SwiGLU, head_dim=128."""
from repro.models.transformer import TransformerConfig

CONFIG = TransformerConfig(
    name="deepseek-7b", n_layers=30, d_model=4096, n_heads=32, n_kv_heads=32,
    d_ff=11008, vocab_size=102400, activation="silu",
)

SMOKE = TransformerConfig(
    name="deepseek-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=192, vocab_size=256, activation="silu", dtype="float32",
    attn_chunk=16,
)
