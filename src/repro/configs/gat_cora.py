"""GAT on Cora [arXiv:1710.10903; paper] — 2L d_hidden=8, 8 heads, attn agg.
d_in / n_classes are shape-dependent (Cora 1433/7; ogbn-products 100/47;
Reddit 602/41) and filled in by the registry per cell."""
from repro.models.gnn import GatConfig

CONFIG = GatConfig(name="gat-cora", n_layers=2, d_hidden=8, n_heads=8)
SMOKE = GatConfig(name="gat-smoke", n_layers=2, d_hidden=4, n_heads=2,
                  d_in=16, n_classes=5)
