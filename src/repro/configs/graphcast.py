"""GraphCast [arXiv:2212.12794; unverified] — encoder-processor-decoder mesh
GNN: 16L d_hidden=512, mesh_refinement=6, sum aggregator, n_vars=227."""
from repro.models.gnn import GraphcastConfig

CONFIG = GraphcastConfig(name="graphcast", n_layers=16, d_hidden=512,
                         n_vars=227, mesh_refinement=6)
SMOKE = GraphcastConfig(name="graphcast-smoke", n_layers=2, d_hidden=16,
                        n_vars=6)
