"""Cell registry: (architecture × input-shape × mesh) -> lowerable step.

Every cell provides the jit-able step function, abstract input structs
(ShapeDtypeStruct — the dry-run never allocates), matching NamedShardings,
and a MODEL_FLOPS estimate for the roofline "useful compute" ratio.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.distributed.sharding import MeshAxes
from repro.models.params import abstract, specs
from repro.optim import AdamWConfig
from repro.optim.adamw import AdamWState

SDS = jax.ShapeDtypeStruct

# ---------------------------------------------------------------------------

LM_ARCHS = ["olmoe-1b-7b", "qwen3-moe-235b-a22b", "mistral-large-123b",
            "gemma-7b", "deepseek-7b"]
GNN_ARCHS = ["gat-cora", "egnn", "mace", "graphcast"]
REC_ARCHS = ["autoint"]

ARCHS = {
    "olmoe-1b-7b": ("lm", "repro.configs.olmoe_1b_7b"),
    "qwen3-moe-235b-a22b": ("lm", "repro.configs.qwen3_moe_235b_a22b"),
    "mistral-large-123b": ("lm", "repro.configs.mistral_large_123b"),
    "gemma-7b": ("lm", "repro.configs.gemma_7b"),
    "deepseek-7b": ("lm", "repro.configs.deepseek_7b"),
    "gat-cora": ("gnn", "repro.configs.gat_cora"),
    "egnn": ("gnn", "repro.configs.egnn"),
    "mace": ("gnn", "repro.configs.mace"),
    "graphcast": ("gnn", "repro.configs.graphcast"),
    "autoint": ("recsys", "repro.configs.autoint"),
}

LM_SHAPES = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1),
}

def _pad512(x: int) -> int:
    """Node/edge counts padded to the 512-device lcm so 1-D sharding divides
    evenly on both production meshes (sentinel padding is the models'
    native convention)."""
    return -(-x // 512) * 512


GNN_SHAPES = {
    "full_graph_sm": dict(n_nodes=_pad512(2708), n_edges=_pad512(10556),
                          d_feat=1433, n_classes=7, batched=False,
                          note="Cora 2708v/10556e padded to /512"),
    "minibatch_lg": dict(n_nodes=_pad512(169984), n_edges=_pad512(168960),
                         d_feat=602, n_classes=41, batched=False,
                         note="sampled block: 1024 seeds, fanout 15-10 over a "
                              "233k-node graph (Reddit-like); shapes are the "
                              "padded sampler output"),
    "ogb_products": dict(n_nodes=_pad512(2449029), n_edges=_pad512(61859140),
                         d_feat=100, n_classes=47, batched=False,
                         note="ogbn-products padded to /512"),
    "molecule": dict(n_nodes=_pad512(30 * 128), n_edges=_pad512(64 * 128),
                     d_feat=16, n_classes=2, batched=True, n_graphs=128),
}

REC_SHAPES = {
    "train_batch": dict(kind="train", batch=65536),
    "serve_p99": dict(kind="serve", batch=512),
    "serve_bulk": dict(kind="serve", batch=262144),
    "retrieval_cand": dict(kind="retrieval", batch=1, n_candidates=1_000_000),
}

SSSP_SHAPES = {"graph1": {}, "graph2": {}, "graph3": {}, "graph4": {}}

SHAPES = {"lm": LM_SHAPES, "gnn": GNN_SHAPES, "recsys": REC_SHAPES,
          "sssp": SSSP_SHAPES}


@dataclasses.dataclass
class Cell:
    arch: str
    shape: str
    kind: str
    step_fn: Callable | None
    args_struct: tuple | None
    in_shardings: tuple | None
    model_flops: float
    note: str = ""
    skip: str | None = None
    donate_argnums: tuple = ()


def _ns(mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def _load(arch: str, smoke: bool = False):
    family, mod = ARCHS[arch]
    m = importlib.import_module(mod)
    return family, (m.SMOKE if smoke else m.CONFIG)


# ---------------------------------------------------------------------------
# LM cells
# ---------------------------------------------------------------------------

def _lm_cell(arch, cfg, shape_id, mesh, ax: MeshAxes,
             scan_layers: bool = True) -> Cell:
    from repro.models import transformer as tf
    sh = LM_SHAPES[shape_id]
    # scan_layers=True -> realistic memory_analysis (loop buffers reused);
    # scan_layers=False -> honest cost_analysis totals (XLA counts a scan
    # body once). The dry-run runs both passes and merges.
    cfg = dataclasses.replace(cfg, scan_layers=scan_layers)
    if not scan_layers and cfg.moe is not None:
        # FLOPs pass: pre-optimization cost analysis does not traverse
        # shard_map bodies; lower the mathematically-identical GSPMD MoE
        # variant for counting (exactness verified to 3e-8 in tests)
        cfg = dataclasses.replace(cfg, moe_impl="gspmd")
    if shape_id == "long_500k":
        return Cell(arch, shape_id, "decode", None, None, None, 0.0,
                    skip="pure full-attention arch: 512K-token dense "
                         "attention is quadratically infeasible; skipped per "
                         "task rule (no SSM/linear-attn variant assigned). "
                         "See DESIGN.md §5.")
    defs = tf.param_defs(cfg, ax)
    p_struct = abstract(defs, cfg.dtype)
    p_spec = specs(defs)
    N_active = cfg.n_active_params()
    B, S = sh["batch"], sh["seq"]
    L, Hkv, Dh = cfg.n_layers, cfg.n_kv_heads, cfg.hd

    if sh["kind"] == "train":
        step = tf.make_train_step(cfg, ax, AdamWConfig())
        batch_struct = {"tokens": SDS((B, S), jnp.int32),
                        "labels": SDS((B, S), jnp.int32)}
        batch_spec = {"tokens": P(ax.data, None), "labels": P(ax.data, None)}
        f32like = jax.tree_util.tree_map(
            lambda s: SDS(s.shape, jnp.float32), p_struct)
        opt_struct = AdamWState(step=SDS((), jnp.int32), m=f32like,
                                v=f32like)
        opt_spec = AdamWState(step=P(), m=p_spec, v=p_spec)
        args = (p_struct, opt_struct, batch_struct)
        shardings = (_ns(mesh, p_spec), _ns(mesh, opt_spec), _ns(mesh, batch_spec))
        flops = 6.0 * N_active * B * S
        return Cell(arch, shape_id, "train", step, args, shardings, flops)

    if sh["kind"] == "prefill":
        step = tf.make_prefill_step(cfg, ax)
        batch_struct = {"tokens": SDS((B, S), jnp.int32)}
        batch_spec = {"tokens": P(ax.data, None)}
        args = (p_struct, batch_struct)
        shardings = (_ns(mesh, p_spec), _ns(mesh, batch_spec))
        flops = 2.0 * N_active * B * S
        return Cell(arch, shape_id, "prefill", step, args, shardings, flops)

    # decode: one new token against a KV cache of seq_len
    step = tf.make_serve_step(cfg, ax)
    cache_struct = tuple(SDS((L, B, S, Hkv, Dh), cfg.dtype) for _ in range(2))
    cache_spec = tuple(P(None, ax.data, ax.model, None, None) for _ in range(2))
    args = (p_struct, SDS((B, 1), jnp.int32), cache_struct, SDS((), jnp.int32))
    shardings = (_ns(mesh, p_spec), NamedSharding(mesh, P(ax.data, None)),
                 _ns(mesh, cache_spec), NamedSharding(mesh, P()))
    # useful flops: dense read of active params + attention over the cache
    flops = 2.0 * N_active * B + 4.0 * L * B * S * Hkv * Dh
    return Cell(arch, shape_id, "decode", step, args, shardings, flops)


# ---------------------------------------------------------------------------
# GNN cells
# ---------------------------------------------------------------------------

def _gnn_batch_struct(arch, cfg, sh, ax):
    N, E, Df = sh["n_nodes"], sh["n_edges"], sh["d_feat"]
    b = {"node_feat": (SDS((N, Df), jnp.float32), P(ax.all, None)),
         "edge_src": (SDS((E,), jnp.int32), P(ax.all)),
         "edge_dst": (SDS((E,), jnp.int32), P(ax.all))}
    if arch == "gat-cora":
        b["labels"] = (SDS((N,), jnp.int32), P(ax.all))
    elif arch == "egnn":
        b["coords"] = (SDS((N, 3), jnp.float32), P(ax.all, None))
        b["labels"] = (SDS((N,), jnp.float32), P(ax.all))
    elif arch == "mace":
        G = sh.get("n_graphs", 1)
        b["coords"] = (SDS((N, 3), jnp.float32), P(ax.all, None))
        b["graph_id"] = (SDS((N,), jnp.int32), P(ax.all))
        b["graph_energy"] = (SDS((G,), jnp.float32), P())
    elif arch == "graphcast":
        b["edge_feat"] = (SDS((E, cfg.d_edge_in), jnp.float32), P(ax.all, None))
        b["labels"] = (SDS((N, cfg.n_vars), jnp.float32), P(ax.all, None))
    struct = {k: v[0] for k, v in b.items()}
    spec = {k: v[1] for k, v in b.items()}
    return struct, spec


def _gnn_flops(arch, cfg, sh):
    N, E, Df = sh["n_nodes"], sh["n_edges"], sh["d_feat"]
    L = cfg.n_layers
    if arch == "gat-cora":
        D, H = cfg.d_hidden, cfg.n_heads
        return 6.0 * (N * Df * H * D + (L - 1) * E * H * D * 4 + E * H * D * 2)
    if arch == "egnn":
        D = cfg.d_hidden
        return 6.0 * L * (E * (2 * D + 1) * D * 2 + E * D * D + N * 2 * D * D * 2)
    if arch == "mace":
        C = cfg.d_hidden
        n_paths = 19  # |{(l1,l2,l3): l<=2}|
        per_edge = n_paths * C * 45          # CG contractions, l<=2 (m-dims <=5)
        per_node = 5 * C * C * 9 * 2         # channel mixes across l
        return 6.0 * L * (E * per_edge + N * per_node)
    if arch == "graphcast":
        D = cfg.d_hidden
        enc = N * Df * D + E * cfg.d_edge_in * D
        per_layer = E * (3 * D) * D + E * D * D + N * (2 * D) * D + N * D * D
        dec = N * D * cfg.n_vars
        return 6.0 * (enc + L * per_layer + dec)
    raise ValueError(arch)


def _gnn_cell(arch, cfg, shape_id, mesh, ax: MeshAxes) -> Cell:
    from repro.models import gnn
    sh = GNN_SHAPES[shape_id]
    # adapt input/output dims to the shape's graph
    if arch == "gat-cora":
        cfg = dataclasses.replace(cfg, d_in=sh["d_feat"], n_classes=sh["n_classes"])
        loss = gnn.gat_loss
        defs = gnn.gat_param_defs(cfg, ax)
    elif arch == "egnn":
        cfg = dataclasses.replace(cfg, d_in=sh["d_feat"])
        loss = gnn.egnn_loss
        defs = gnn.egnn_param_defs(cfg, ax)
    elif arch == "mace":
        loss = gnn.mace_loss
        defs = gnn.mace_param_defs(cfg, ax)
        if not sh["batched"]:
            sh = dict(sh, n_graphs=1)
    elif arch == "graphcast":
        # inputs follow the shape's d_feat; outputs stay n_vars=227
        loss = gnn.graphcast_loss
        defs = gnn.graphcast_param_defs(cfg, ax)
        defs["node_enc"] = gnn.mlp_defs(
            [sh["d_feat"], cfg.d_hidden, cfg.d_hidden], ln=True)
    else:
        raise ValueError(arch)
    p_struct = abstract(defs)
    p_spec = specs(defs)
    batch_struct, batch_spec = _gnn_batch_struct(arch, cfg, sh, ax)
    if arch == "graphcast":
        batch_struct["labels"] = SDS((sh["n_nodes"], cfg.n_vars), jnp.float32)
        batch_spec["labels"] = P(ax.all, None)

    step = gnn.make_gnn_train_step(loss, cfg, ax, AdamWConfig())
    f32like = jax.tree_util.tree_map(lambda s: SDS(s.shape, jnp.float32), p_struct)
    opt_struct = AdamWState(step=SDS((), jnp.int32), m=f32like, v=f32like)
    opt_spec = AdamWState(step=P(), m=p_spec, v=p_spec)
    args = (p_struct, opt_struct, batch_struct)
    shardings = (_ns(mesh, p_spec), _ns(mesh, opt_spec), _ns(mesh, batch_spec))
    return Cell(arch, shape_id, "train", step, args, shardings,
                _gnn_flops(arch, cfg, sh), note=sh.get("note", ""))


# ---------------------------------------------------------------------------
# recsys cells
# ---------------------------------------------------------------------------

def _rec_cell(arch, cfg, shape_id, mesh, ax: MeshAxes) -> Cell:
    from repro.models import autoint as ai
    sh = REC_SHAPES[shape_id]
    B = sh["batch"]
    defs = ai.autoint_param_defs(cfg, ax)
    p_struct = abstract(defs)
    p_spec = specs(defs)
    F, Lh = cfg.n_sparse, cfg.multi_hot
    idx_struct = SDS((B, F, Lh), jnp.int32)
    idx_spec = P(ax.data, None, None)

    D, A, H, nL = cfg.embed_dim, cfg.d_attn, cfg.n_heads, cfg.n_attn_layers
    attn_flops = nL * (3 * B * F * (H * A) * (H * A) + 2 * B * H * F * F * A)
    embed_flops = B * F * Lh * D
    base = attn_flops + embed_flops + B * F * H * A * 64

    if sh["kind"] == "train":
        step = ai.make_autoint_train_step(cfg, ax, AdamWConfig())
        batch_struct = {"sparse_idx": idx_struct, "labels": SDS((B,), jnp.int32)}
        batch_spec = {"sparse_idx": idx_spec, "labels": P(ax.data)}
        f32like = jax.tree_util.tree_map(lambda s: SDS(s.shape, jnp.float32), p_struct)
        opt_struct = AdamWState(step=SDS((), jnp.int32), m=f32like, v=f32like)
        opt_spec = AdamWState(step=P(), m=p_spec, v=p_spec)
        args = (p_struct, opt_struct, batch_struct)
        shardings = (_ns(mesh, p_spec), _ns(mesh, opt_spec), _ns(mesh, batch_spec))
        return Cell(arch, shape_id, "train", step, args, shardings, 3.0 * base)

    if sh["kind"] == "serve":
        step = ai.make_autoint_serve_step(cfg, ax)
        batch_struct = {"sparse_idx": idx_struct}
        batch_spec = {"sparse_idx": idx_spec}
        args = (p_struct, batch_struct)
        shardings = (_ns(mesh, p_spec), _ns(mesh, batch_spec))
        return Cell(arch, shape_id, "serve", step, args, shardings, base)

    Nc = sh["n_candidates"]
    step = ai.make_retrieval_step(cfg, ax)
    batch_struct = {"sparse_idx": idx_struct,
                    "cand_vecs": SDS((Nc, cfg.d_retrieval), jnp.float32)}
    # B=1 query replicated; candidates sharded over the model axis
    batch_spec = {"sparse_idx": P(None, None, None),
                  "cand_vecs": P(ax.model, None)}
    args = (p_struct, batch_struct)
    shardings = (_ns(mesh, p_spec), _ns(mesh, batch_spec))
    return Cell(arch, shape_id, "retrieval", step, args, shardings,
                base + 2.0 * B * Nc * cfg.d_retrieval)


# ---------------------------------------------------------------------------
# SSSP (paper) cells
# ---------------------------------------------------------------------------

def _sssp_abstract_shards(gspec, n_parts: int):
    from repro.core.shards import SsspShards
    s = gspec.shard_shapes(n_parts)
    Pn = n_parts
    i32, f32, b_ = jnp.int32, jnp.float32, jnp.bool_
    return SsspShards(
        loc_src=SDS((Pn, s["e_loc"]), i32), loc_dst=SDS((Pn, s["e_loc"]), i32),
        loc_w=SDS((Pn, s["e_loc"]), f32),
        cut_src=SDS((Pn, s["e_cut"]), i32), cut_w=SDS((Pn, s["e_cut"]), f32),
        cut_seg=SDS((Pn, s["e_cut"]), i32),
        slot_owner=SDS((Pn, s["S"]), i32), slot_dstl=SDS((Pn, s["S"]), i32),
        slot_pos=SDS((Pn, s["S"]), i32), slot_valid=SDS((Pn, s["S"]), b_),
        recv_idx=SDS((Pn, Pn, s["C"]), i32),
        tri_uj=SDS((Pn, s["T"]), i32), tri_ui=SDS((Pn, s["T"]), i32),
        tri_ij=SDS((Pn, s["T"]), i32), tri_valid=SDS((Pn, s["T"]), b_),
        inter_edges=SDS((Pn,), i32),
        n_vertices=gspec.n_vertices, n_parts=Pn, block=s["block"],
    )


def _sssp_cell(shape_id, mesh, ax: MeshAxes, sssp_cfg=None) -> Cell:
    from repro.configs.sssp_paper import GRAPHS
    from repro.core.sssp import SsspConfig, build_shmap_solver
    gspec = GRAPHS[shape_id]
    n_parts = mesh.size
    cfg = sssp_cfg or SsspConfig(max_rounds=64)
    shards = _sssp_abstract_shards(gspec, n_parts)
    solver = build_shmap_solver(shards, cfg, mesh, ax.all, source=0)
    spec_tree = jax.tree_util.tree_map(lambda _: P(ax.all), shards)
    shardings = (_ns(mesh, spec_tree),)
    # one full relaxation of every edge + the exchange, per round; report
    # per-round useful work (min-plus relax = 1 add + 1 min per edge)
    flops = 2.0 * gspec.n_edges
    return Cell("sp-async", shape_id, "sssp",
                lambda sh: solver(sh), (shards,), shardings, flops,
                note=f"cut={gspec.cut_fraction}, rounds capped at "
                     f"{cfg.max_rounds} for the dry-run lowering")


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------

def build_cell(arch: str, shape_id: str, mesh, ax: MeshAxes,
               smoke: bool = False, **kw) -> Cell:
    if arch in ("sp-async", "sssp"):
        return _sssp_cell(shape_id, mesh, ax, kw.get("sssp_cfg"))
    family, cfg = _load(arch, smoke)
    if family == "lm":
        return _lm_cell(arch, cfg, shape_id, mesh, ax,
                        scan_layers=kw.get("scan_layers", True))
    if family == "gnn":
        return _gnn_cell(arch, cfg, shape_id, mesh, ax)
    return _rec_cell(arch, cfg, shape_id, mesh, ax)


def list_cells(include_sssp: bool = True):
    out = []
    for arch, (family, _) in ARCHS.items():
        for shape_id in SHAPES[family]:
            out.append((arch, shape_id))
    if include_sssp:
        for g in SSSP_SHAPES:
            out.append(("sp-async", g))
    return out
