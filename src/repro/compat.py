"""Version compatibility for the jax APIs this repo straddles.

The codebase targets the current jax API (``jax.shard_map``,
``jax.make_mesh(..., axis_types=...)``, ``check_vma``); older releases
(<= 0.4.x) ship the same functionality as ``jax.experimental.shard_map``
(with ``check_rep``) and a ``make_mesh`` without ``axis_types``. Everything
runtime-critical goes through these two wrappers so a single interpreter
can run either jax.
"""
from __future__ import annotations

import jax

try:  # jax >= 0.5
    _shard_map = jax.shard_map
    _CHECK_KW = "check_vma"
except AttributeError:
    from jax.experimental.shard_map import shard_map as _shard_map
    _CHECK_KW = "check_rep"


def shard_map(f, *, mesh=None, in_specs, out_specs,
              check_vma: bool | None = None):
    kwargs = {} if check_vma is None else {_CHECK_KW: check_vma}
    if mesh is None and _CHECK_KW == "check_rep":
        # old shard_map cannot infer the mesh from context — resolve it here
        from jax._src.mesh import thread_resources
        mesh = thread_resources.env.physical_mesh
        if mesh.empty:
            raise ValueError("shard_map without mesh requires an active "
                             "mesh context (compat.set_mesh)")
    if mesh is not None:
        kwargs["mesh"] = mesh
    return _shard_map(f, in_specs=in_specs, out_specs=out_specs, **kwargs)


def set_mesh(mesh):
    """``jax.set_mesh`` context; falls back to the legacy ``with mesh:``
    resource context on older jax."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    if hasattr(jax.sharding, "use_mesh"):
        return jax.sharding.use_mesh(mesh)
    return mesh  # jax.sharding.Mesh is itself a context manager


def make_mesh(axis_shapes, axis_names, *, auto_axes: bool = True):
    """``jax.make_mesh`` with Auto axis_types where supported."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None or not auto_axes:
        return jax.make_mesh(axis_shapes, axis_names)
    return jax.make_mesh(axis_shapes, axis_names,
                         axis_types=(axis_type.Auto,) * len(axis_names))
