"""End-to-end training driver with checkpoint/restart fault tolerance.

Runs reduced ("smoke") or full configs of any registered arch on whatever
mesh exists. Demonstrates the production loop:

  - data pipeline -> device batches
  - jitted train step (GSPMD-sharded)
  - periodic checkpoints (atomic commit, keep-K)
  - crash-safe resume: on start, restores the latest complete step and
    continues (elastic: the restore reshards onto the current mesh)

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch deepseek-7b --smoke \
      --steps 200 --ckpt-dir /tmp/ckpt --ckpt-every 50
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.checkpoint import CheckpointManager
from repro.configs.registry import _load
from repro.data import TokenStream, RecsysBatcher
from repro.distributed.sharding import MeshAxes
from repro.launch.mesh import make_host_mesh
from repro.models.params import materialize
from repro.optim import AdamWConfig
from repro.optim.adamw import adamw_init


def build_lm(cfg, ax, batch, seq, opt_cfg):
    from repro.models import transformer as tf
    defs = tf.param_defs(cfg, ax)
    params = materialize(defs, jax.random.key(0), cfg.dtype)
    step = tf.make_train_step(cfg, ax, opt_cfg)
    data = TokenStream(batch, seq, cfg.vocab_size)
    return params, step, data


def build_recsys(cfg, ax, batch, opt_cfg):
    from repro.models import autoint as ai
    defs = ai.autoint_param_defs(cfg, ax)
    params = materialize(defs, jax.random.key(0))
    step = ai.make_autoint_train_step(cfg, ax, opt_cfg)
    data = RecsysBatcher(batch, cfg.n_sparse, cfg.vocab_per_field,
                         cfg.multi_hot)
    return params, step, data


def build_gnn(arch, cfg, ax, opt_cfg):
    from repro.models import gnn
    from repro.data import GraphBatcher
    rng = np.random.default_rng(0)
    N, E = 256, 1024
    src = rng.integers(0, N, E).astype(np.int32)
    dst = rng.integers(0, N, E).astype(np.int32)
    loss = {"gat-cora": gnn.gat_loss, "egnn": gnn.egnn_loss,
            "mace": gnn.mace_loss, "graphcast": gnn.graphcast_loss}[arch]
    defs = {"gat-cora": gnn.gat_param_defs, "egnn": gnn.egnn_param_defs,
            "mace": gnn.mace_param_defs,
            "graphcast": gnn.graphcast_param_defs}[arch](cfg, ax)
    params = materialize(defs, jax.random.key(0))
    step = gnn.make_gnn_train_step(loss, cfg, ax, opt_cfg)

    def batch_builder(i):
        b = dict(edge_src=jnp.asarray(src), edge_dst=jnp.asarray(dst))
        if arch == "gat-cora":
            b["node_feat"] = jnp.asarray(rng.standard_normal((N, cfg.d_in)), jnp.float32)
            b["labels"] = jnp.asarray(rng.integers(0, cfg.n_classes, N), jnp.int32)
        elif arch == "egnn":
            b["node_feat"] = jnp.asarray(rng.standard_normal((N, cfg.d_in)), jnp.float32)
            b["coords"] = jnp.asarray(rng.standard_normal((N, 3)), jnp.float32)
            b["labels"] = jnp.asarray(rng.standard_normal(N), jnp.float32)
        elif arch == "mace":
            b["node_feat"] = jnp.asarray(rng.integers(0, 10, (N, 1)), jnp.float32)
            b["coords"] = jnp.asarray(rng.standard_normal((N, 3)) * 2, jnp.float32)
            b["graph_id"] = jnp.asarray(np.repeat(np.arange(8), N // 8), jnp.int32)
            b["graph_energy"] = jnp.asarray(rng.standard_normal(8), jnp.float32)
        else:
            b["node_feat"] = jnp.asarray(rng.standard_normal((N, cfg.n_vars)), jnp.float32)
            b["edge_feat"] = jnp.asarray(rng.standard_normal((E, cfg.d_edge_in)), jnp.float32)
            b["labels"] = jnp.asarray(rng.standard_normal((N, cfg.n_vars)), jnp.float32)
        return b

    return params, step, GraphBatcher(batch_builder)


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="deepseek-7b")
    p.add_argument("--smoke", action="store_true")
    p.add_argument("--steps", type=int, default=100)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=64)
    p.add_argument("--lr", type=float, default=1e-3)
    p.add_argument("--ckpt-dir", default=None)
    p.add_argument("--ckpt-every", type=int, default=50)
    p.add_argument("--log-every", type=int, default=10)
    args = p.parse_args(argv)

    mesh = make_host_mesh()
    ax = MeshAxes(data=("data",))
    family, cfg = _load(args.arch, smoke=args.smoke)
    opt_cfg = AdamWConfig(lr=args.lr)

    if family == "lm":
        params, step_fn, data = build_lm(cfg, ax, args.batch, args.seq, opt_cfg)
    elif family == "recsys":
        params, step_fn, data = build_recsys(cfg, ax, args.batch, opt_cfg)
    else:
        params, step_fn, data = build_gnn(args.arch, cfg, ax, opt_cfg)

    opt_state = adamw_init(params)
    step_fn = jax.jit(step_fn, donate_argnums=(0, 1))
    start = 0
    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    if mgr is not None and mgr.latest() is not None:
        (params, opt_state), start = mgr.restore((params, opt_state))
        print(f"resumed from step {start}")

    it = iter(data)
    losses = []
    with compat.set_mesh(mesh):
        t0 = time.time()
        for s in range(start, args.steps):
            batch = next(it)
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            losses.append(float(metrics["loss"]))
            if (s + 1) % args.log_every == 0:
                dt = (time.time() - t0) / args.log_every
                print(f"step {s+1}: loss={losses[-1]:.4f} "
                      f"({dt*1e3:.0f} ms/step)")
                t0 = time.time()
            if mgr is not None and (s + 1) % args.ckpt_every == 0:
                mgr.save(s + 1, (params, opt_state))
    print(f"final loss: {losses[-1]:.4f} (first: {losses[0]:.4f})")
    return losses


if __name__ == "__main__":
    main()
