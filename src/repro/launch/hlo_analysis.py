"""Parse collective traffic and roofline terms out of compiled artifacts.

``cost_analysis`` gives FLOPs and HBM bytes; collective bytes are NOT in it,
so we parse the (post-SPMD-partitioning) HLO text and sum result-shape bytes
of every collective op, converting to per-device wire bytes with the
standard algorithm models:

  all-reduce        2 * bytes * (P-1)/P      (ring RS + AG)
  all-gather        bytes * (P-1)/P          (result bytes include the P×)
  reduce-scatter    bytes * (P-1)/P          (input bytes)
  all-to-all        bytes * (P-1)/P
  collective-permute bytes                   (one hop)

Hardware model (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import re

PEAK_FLOPS = 197e12        # bf16 per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link (per-device injection proxy)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _while_body_regions(hlo_text: str) -> set:
    """Names of computations used as while-loop bodies (scan lowers to
    while; collectives inside run once per trip, so their bytes must be
    scaled by the trip count)."""
    bodies = set()
    for m in re.finditer(r"while\(.*?\).*?body=%?([\w.\-]+)", hlo_text):
        bodies.add(m.group(1))
    return bodies


def collective_bytes(hlo_text: str, n_devices: int,
                     loop_scale: int = 1) -> dict:
    """Sum per-collective wire bytes (per device) from HLO module text.
    ``loop_scale``: multiplier applied to collectives inside while-loop
    bodies (= scan trip count, e.g. n_layers for scan-over-layers)."""
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    frac = (n_devices - 1) / max(n_devices, 1)
    bodies = _while_body_regions(hlo_text) if loop_scale != 1 else set()
    current_comp = None
    in_body = False
    for line in hlo_text.splitlines():
        ls = line.strip()
        mc = re.match(r"%?([\w.\-]+) \([\w.\-]*:? ?.*\) -> .+ \{$", ls)
        if mc:
            current_comp = mc.group(1)
            in_body = current_comp in bodies
            continue
        m = re.match(r"%?[\w.\-]+ = (.+?) (all-reduce|all-gather|"
                     r"reduce-scatter|all-to-all|collective-permute)", ls)
        if not m:
            continue
        shp, op = m.group(1), m.group(2)
        b = _shape_bytes(shp)
        # XLA's AllReducePromotion pass upcasts bf16 all-reduces to f32 on
        # the CPU backend (reducer named ..._promoted). TPU reduces bf16 on
        # the wire with f32 accumulation — count the pre-promotion payload.
        if op == "all-reduce" and "_promoted" in ls:
            b //= 2
        if op == "all-reduce":
            wire = 2 * b * frac
        elif op == "collective-permute":
            wire = b
        else:
            wire = b * frac
        scale = loop_scale if in_body else 1
        out[op] += int(wire * scale)
        counts[op] += 1
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    out["counts"] = counts
    return out


def roofline_terms(flops: float, hbm_bytes: float, coll_bytes: float,
                   n_devices: int, model_flops: float = 0.0) -> dict:
    """``flops``/``hbm_bytes`` come from the PARTITIONED executable's
    cost_analysis and are PER-DEVICE (verified against 6·N·D for multiple
    cells); collective bytes (parsed from the partitioned HLO) are
    per-device wire traffic. ``model_flops`` is the GLOBAL useful work."""
    compute_s = flops / PEAK_FLOPS
    memory_s = hbm_bytes / HBM_BW
    coll_s = coll_bytes / ICI_BW
    dominant = max((compute_s, "compute"), (memory_s, "memory"),
                   (coll_s, "collective"))[1]
    hlo_global = flops * n_devices
    return dict(
        compute_s=compute_s, memory_s=memory_s, collective_s=coll_s,
        dominant=dominant,
        model_flops=model_flops,
        useful_ratio=(model_flops / hlo_global) if hlo_global else 0.0,
        bound_s=max(compute_s, memory_s, coll_s),
        roofline_fraction=(compute_s / max(compute_s, memory_s, coll_s)
                           if max(compute_s, memory_s, coll_s) > 0 else 0.0),
    )
