"""SP-Async production runner: generate/partition/solve/validate.

    PYTHONPATH=src python -m repro.launch.sssp_run --graph rmat --scale 12 \
        --parts 8 --exchange bucket --toka toka2 --solver delta

Batched query mode — K sources amortize one partition/preprocess over the
whole batch and ride a single compiled solve (the run goes through
``SsspEngine``: sources are traced, the batch pads to the next K-bucket,
and a later run of the same bucket shape would reuse the compiled program):

    ... repro.launch.sssp_run --sources 0,17,1999        # explicit batch
    ... repro.launch.sssp_run --num-sources 16 --batch   # sampled batch

Backends: ``sim`` (single device, any partition count) and ``shmap``
(shard_map over real devices — on a TPU pod this is the deployment path;
here it requires XLA_FLAGS device-count spoofing, see tests/test_multidevice).
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core import FaultPlan, SsspConfig, SsspEngine, build_shards
from repro.graph import (dijkstra_reference, rmat_graph, road_grid_graph,
                         random_graph)


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--graph", choices=["rmat", "road", "random"], default="rmat")
    p.add_argument("--scale", type=int, default=12)
    p.add_argument("--edge-factor", type=int, default=8)
    p.add_argument("--side", type=int, default=64)
    p.add_argument("--parts", type=int, default=8)
    p.add_argument("--source", type=int, default=-1)
    p.add_argument("--sources", default=None,
                   help="comma-separated source list; solves the whole "
                        "batch in one multi-query run")
    p.add_argument("--num-sources", type=int, default=0,
                   help="sample this many sources for a batched run")
    p.add_argument("--batch", action="store_true",
                   help="batched query mode; equivalent to --num-sources 8 "
                        "unless --sources/--num-sources pick the batch")
    p.add_argument("--exchange", default="bucket",
                   choices=["bucket", "pmin", "a2a_dense", "async",
                            "async_bucket", "async_ppermute"],
                   help="message exchange: synchronous (bucket/pmin/"
                        "a2a_dense barrier every round) or deferred "
                        "(async/async_bucket double-buffer the all-to-all, "
                        "async_ppermute streams bidirectional ring hops) — "
                        "deferred exchanges overlap round r's relax with "
                        "round r-1's delivery, same distances, more rounds")
    p.add_argument("--async-lag", type=int, default=1,
                   help="in-flight buffer depth for --exchange async/"
                        "async_bucket (rounds between send and delivery; "
                        "async_ppermute's lag is the ring distance)")
    p.add_argument("--toka", default="toka0",
                   choices=["toka0", "toka1", "toka2", "toka3"])
    p.add_argument("--solver", default="bellman",
                   choices=["bellman", "delta", "pallas"])
    p.add_argument("--send-backend", default="xla", choices=["xla", "pallas"],
                   help="cut-edge segment-min pack: XLA or the slot-tiled "
                        "Pallas kernel")
    p.add_argument("--merge-backend", default="xla", choices=["xla", "pallas"],
                   help="incoming scatter-min: XLA or the msg-tiled Pallas "
                        "kernel")
    p.add_argument("--round", default="staged", choices=["staged", "fused"],
                   help="round pipeline shape: 'staged' dispatches "
                        "local/send/exchange/merge separately; 'fused' runs "
                        "merge + relax fixpoint + send pack as ONE Pallas "
                        "megakernel (2 dispatches/round, overrides "
                        "--solver/--send-backend/--merge-backend)")
    p.add_argument("--delta", type=float, default=4.0)
    p.add_argument("--no-prune", action="store_true")
    p.add_argument("--backend", default="sim", choices=["sim", "shmap"])
    p.add_argument("--warm-start", default="none",
                   choices=["none", "landmark"],
                   help="seed every query's distances from the landmark cache "
                        "(triangle-inequality upper bounds; requires "
                        "symmetric/undirected distances) instead of +inf")
    p.add_argument("--landmarks", type=int, default=0,
                   help="precompute this many landmark pivot solves before "
                        "serving (required with --warm-start landmark)")
    p.add_argument("--result-cache", type=int, default=0,
                   help="LRU size for exact-repeat query results "
                        "(0 disables; hits are served with zero rounds)")
    p.add_argument("--fault-drop", type=float, default=0.0,
                   help="message drop probability (fault injection)")
    p.add_argument("--fault-delay", type=float, default=0.0,
                   help="message delay probability (bounded in-carry queue)")
    p.add_argument("--fault-duplicate", type=float, default=0.0,
                   help="message duplication probability")
    p.add_argument("--fault-reorder", type=float, default=0.0,
                   help="message reorder probability (defer one round)")
    p.add_argument("--fault-seed", type=int, default=0,
                   help="seed of the deterministic fault stream")
    p.add_argument("--resend-period", type=int, default=0,
                   help="anti-entropy: retransmit last_sent minima every N "
                        "rounds to heal dropped messages (0 = off; with "
                        "drops and no resend, solves degrade)")
    p.add_argument("--validate", action="store_true")
    args = p.parse_args()
    if args.warm_start == "landmark" and args.landmarks < 1:
        p.error("--warm-start landmark requires --landmarks N (N >= 1)")
    if args.async_lag < 1:
        p.error("--async-lag must be >= 1 (1 = double-buffered)")
    if args.async_lag != 1 and args.exchange not in ("async", "async_bucket"):
        p.error("--async-lag only applies to --exchange async/async_bucket")
    faults = None
    if (args.fault_drop or args.fault_delay or args.fault_duplicate
            or args.fault_reorder):
        faults = FaultPlan(drop=args.fault_drop, delay=args.fault_delay,
                           duplicate=args.fault_duplicate,
                           reorder=args.fault_reorder, seed=args.fault_seed,
                           resend_period=args.resend_period)

    if args.graph == "rmat":
        g = rmat_graph(scale=args.scale, edge_factor=args.edge_factor, seed=0)
    elif args.graph == "road":
        g = road_grid_graph(side=args.side, seed=0)
    else:
        g = random_graph(n=1 << args.scale, m=(1 << args.scale) * args.edge_factor,
                         seed=0)
    if args.sources:
        sources = [int(s) for s in args.sources.split(",")]
    elif args.batch or args.num_sources:
        k = args.num_sources or 8
        rng = np.random.default_rng(0)
        sources = sorted(int(s) for s in
                         rng.choice(g.n_vertices, size=k, replace=False))
    else:
        sources = [args.source if args.source >= 0 else int(g.src[0])]
    batched = len(sources) > 1
    print(f"graph: {g.n_vertices}v {g.n_edges}e, "
          f"sources={sources if batched else sources[0]}, P={args.parts}")

    t0 = time.time()
    sh = build_shards(g, args.parts, enumerate_triangles=not args.no_prune)
    print(f"partition+preprocess: {time.time() - t0:.2f}s "
          f"(cut edges: {int(np.asarray(sh.inter_edges).sum())}) "
          f"— amortized over {len(sources)} quer"
          f"{'ies' if batched else 'y'}")

    cfg = SsspConfig(exchange=args.exchange, toka=args.toka,
                     local_solver=args.solver, delta=args.delta,
                     send_backend=args.send_backend,
                     merge_backend=args.merge_backend,
                     warm_start=args.warm_start, round=args.round,
                     prune_online=not args.no_prune, faults=faults,
                     async_lag=args.async_lag)
    if args.backend == "sim":
        engine = SsspEngine.build(sh, cfg, result_cache=args.result_cache)
    else:
        import jax
        from repro import compat
        n_dev = len(jax.devices())
        mesh = compat.make_mesh((n_dev,), ("data",))
        engine = SsspEngine.build(sh, cfg, backend="shmap", mesh=mesh,
                                  axis_names=("data",),
                                  result_cache=args.result_cache)
    if args.landmarks:
        rng = np.random.default_rng(7)
        pivots = sorted(int(s) for s in
                        rng.choice(g.n_vertices, size=args.landmarks,
                                   replace=False))
        t0 = time.time()
        lm = engine.precompute_landmarks(pivots)
        print(f"landmarks: {lm.n_landmarks} pivots solved in "
              f"{time.time() - t0:.2f}s ({lm.nbytes_per_shard} B/shard; "
              f"warm_start={cfg.warm_start})")
    res = engine.solve(sources)
    dists, stats = res.dist, res.stats
    dt = res.wall_s
    mteps = int(stats.relaxations) / dt / 1e6
    qps = len(sources) / dt
    print(f"solve: {dt:.3f}s (compile {res.compile_s:.3f}s, "
          f"bucket K={res.bucket_k})  rounds={int(stats.rounds)} "
          f"relax={int(stats.relaxations)} msgs={int(stats.msgs_sent)} "
          f"pruned={int(stats.pruned_edges)}  MTEPS={mteps:.1f} "
          f"queries/s={qps:.2f}"
          + (" [warm-started]" if res.warm_started else ""))
    print(f"status: {res.status} "
          f"(converged {int(res.q_converged.sum())}/{len(sources)} queries)")
    if args.exchange.startswith("async"):
        print(f"async: overlap={res.overlap_fraction:.2f} "
              f"({int(stats.overlap_rounds)}/{int(stats.rounds)} rounds "
              f"comm/compute overlapped)  "
              f"stale_merges={int(np.asarray(stats.stale_merges).sum())}  "
              f"bytes_moved={int(stats.bytes_moved)}  lag={args.async_lag}")
    if faults is not None:
        print(f"faults: {faults}  stale_merges={int(stats.stale_merges)} "
              f"resends={int(stats.resends)}")
    if args.result_cache:
        rerun = engine.solve(sources)
        print(f"repeat solve: {rerun.wall_s * 1e3:.2f}ms "
              f"cache_hits={rerun.cache_hits}/{len(sources)} "
              f"rounds={int(rerun.stats.rounds)} (exact repeats ride the "
              f"result LRU, zero rounds)")
    if batched:
        qr = np.asarray(stats.q_rounds)
        qx = np.asarray(stats.q_relaxations)
        for k, s in enumerate(sources):
            reach = int(np.isfinite(dists[k]).sum())
            print(f"  query[{k}] source={s}: rounds={int(qr[k])} "
                  f"relax={int(qx[k])} reachable={reach}/{g.n_vertices}")
    else:
        print(f"reachable: {int(np.isfinite(dists[0]).sum())}/{g.n_vertices}")

    if args.validate:
        # unconverged queries fail LOUDLY before the distance check even
        # runs: an upper-bound row can happen to match Dijkstra on easy
        # graphs, and "validated" must never describe a degraded solve
        conv = res.q_converged
        if res.status != "converged" or not conv.all():
            bad = [sources[k] for k in np.flatnonzero(~conv)]
            print(f"validation FAILED: status={res.status}, unconverged "
                  f"sources={bad}")
            raise SystemExit(1)
        ok = True
        for k, s in enumerate(sources):
            ref = dijkstra_reference(g, s)
            ok &= np.allclose(dists[k], ref, rtol=1e-5, atol=1e-4)
        print(f"validation vs Dijkstra ({len(sources)} quer"
              f"{'ies' if batched else 'y'}): {'OK' if ok else 'MISMATCH'}")
        if not ok:
            raise SystemExit(1)


if __name__ == "__main__":
    main()
