"""SP-Async production runner: generate/partition/solve/validate.

    PYTHONPATH=src python -m repro.launch.sssp_run --graph rmat --scale 12 \
        --parts 8 --exchange bucket --toka toka2 --solver delta

Backends: ``sim`` (single device, any partition count) and ``shmap``
(shard_map over real devices — on a TPU pod this is the deployment path;
here it requires XLA_FLAGS device-count spoofing, see tests/test_multidevice).
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core import SsspConfig, build_shards, solve_sim, solve_shmap
from repro.graph import (dijkstra_reference, rmat_graph, road_grid_graph,
                         random_graph)


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--graph", choices=["rmat", "road", "random"], default="rmat")
    p.add_argument("--scale", type=int, default=12)
    p.add_argument("--edge-factor", type=int, default=8)
    p.add_argument("--side", type=int, default=64)
    p.add_argument("--parts", type=int, default=8)
    p.add_argument("--source", type=int, default=-1)
    p.add_argument("--exchange", default="bucket",
                   choices=["bucket", "pmin", "a2a_dense"])
    p.add_argument("--toka", default="toka0",
                   choices=["toka0", "toka1", "toka2"])
    p.add_argument("--solver", default="bellman",
                   choices=["bellman", "delta", "pallas"])
    p.add_argument("--delta", type=float, default=4.0)
    p.add_argument("--no-prune", action="store_true")
    p.add_argument("--backend", default="sim", choices=["sim", "shmap"])
    p.add_argument("--validate", action="store_true")
    args = p.parse_args()

    if args.graph == "rmat":
        g = rmat_graph(scale=args.scale, edge_factor=args.edge_factor, seed=0)
    elif args.graph == "road":
        g = road_grid_graph(side=args.side, seed=0)
    else:
        g = random_graph(n=1 << args.scale, m=(1 << args.scale) * args.edge_factor,
                         seed=0)
    source = args.source if args.source >= 0 else int(g.src[0])
    print(f"graph: {g.n_vertices}v {g.n_edges}e, source={source}, "
          f"P={args.parts}")

    t0 = time.time()
    sh = build_shards(g, args.parts, enumerate_triangles=not args.no_prune)
    print(f"partition+preprocess: {time.time() - t0:.2f}s "
          f"(cut edges: {int(np.asarray(sh.inter_edges).sum())})")

    cfg = SsspConfig(exchange=args.exchange, toka=args.toka,
                     local_solver=args.solver, delta=args.delta,
                     prune_online=not args.no_prune)
    t0 = time.time()
    if args.backend == "sim":
        dist, stats = solve_sim(sh, source, cfg)
    else:
        import jax
        from repro import compat
        n_dev = len(jax.devices())
        mesh = compat.make_mesh((n_dev,), ("data",))
        dist, stats = solve_shmap(sh, source, cfg, mesh, ("data",))
    dt = time.time() - t0
    mteps = int(stats.relaxations) / dt / 1e6
    print(f"solve: {dt:.3f}s  rounds={int(stats.rounds)} "
          f"relax={int(stats.relaxations)} msgs={int(stats.msgs_sent)} "
          f"pruned={int(stats.pruned_edges)}  MTEPS={mteps:.1f}")
    print(f"reachable: {int(np.isfinite(dist).sum())}/{g.n_vertices}")

    if args.validate:
        ref = dijkstra_reference(g, source)
        ok = np.allclose(dist, ref, rtol=1e-5, atol=1e-4)
        print(f"validation vs Dijkstra: {'OK' if ok else 'MISMATCH'}")
        if not ok:
            raise SystemExit(1)


if __name__ == "__main__":
    main()
