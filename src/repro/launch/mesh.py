"""Production mesh construction.

A FUNCTION, not a module-level constant — importing this module never
touches jax device state (device count is locked at first jax init)."""
from __future__ import annotations

from repro import compat


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_host_mesh(shape=(1, 1), axes=("data", "model")):
    """Small mesh over whatever devices exist (tests / smoke runs)."""
    return compat.make_mesh(shape, axes)
