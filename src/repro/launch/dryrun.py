import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above MUST stay first — jax locks the device count at first
init, and the production meshes need 512 placeholder host devices.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch olmoe-1b-7b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all            # every cell
  PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod

Per cell: jit(step).lower(*abstract_inputs).compile(), then record
memory_analysis(), cost_analysis(), and collective bytes parsed from the
HLO into benchmarks/artifacts/dryrun/<cell>.json.
"""
import argparse
import json
import time
import traceback

import jax

from repro import compat
from repro.configs.registry import ARCHS, build_cell, list_cells

ARCH_FAMILY = {a: fam for a, (fam, _) in ARCHS.items()}
from repro.distributed.sharding import mesh_axes
from repro.launch.mesh import make_production_mesh
from repro.launch.hlo_analysis import collective_bytes, roofline_terms

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "benchmarks", "artifacts", "dryrun")


def _compile_and_measure(cell, mesh, loop_scale: int = 1) -> dict:
    t0 = time.time()
    with compat.set_mesh(mesh):
        jitted = jax.jit(cell.step_fn, in_shardings=cell.in_shardings)
        lowered = jitted.lower(*cell.args_struct)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    coll = collective_bytes(hlo, mesh.size, loop_scale=loop_scale)
    flops = float(cost.get("flops", 0.0)) if cost else 0.0
    hbm = float(cost.get("bytes accessed", 0.0)) if cost else 0.0
    return dict(
        lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
        hlo_flops=flops, hlo_bytes=hbm, collectives=coll,
        memory=dict(
            argument_bytes=getattr(mem, "argument_size_in_bytes", None),
            output_bytes=getattr(mem, "output_size_in_bytes", None),
            temp_bytes=getattr(mem, "temp_size_in_bytes", None),
            peak_bytes=getattr(mem, "peak_memory_in_bytes", None)))


def _lower_cost_only(cell, mesh) -> dict:
    """Unrolled flops pass without XLA compile: trace+lower, read the
    pre-optimization cost analysis (GLOBAL totals; divided by mesh.size
    for per-device roofline terms)."""
    t0 = time.time()
    with compat.set_mesh(mesh):
        jitted = jax.jit(cell.step_fn, in_shardings=cell.in_shardings)
        lowered = jitted.lower(*cell.args_struct)
    cost = lowered.cost_analysis() or {}
    return dict(
        lower_s=round(time.time() - t0, 2),
        hlo_flops=float(cost.get("flops", 0.0)) / mesh.size,
        hlo_bytes=float(cost.get("bytes accessed", 0.0)) / mesh.size)


def run_cell(arch: str, shape: str, multi_pod: bool, out_dir: str,
             force: bool = False, flops_pass: bool = True) -> dict:
    """LM cells take two passes: scan-over-layers (memory_analysis with
    loop buffer reuse — the 'does it fit' proof) and unrolled (cost_analysis
    totals — XLA counts a scan body once, so the scanned pass under-reports
    FLOPs/collectives by ~n_layers). Other families are loop-free (or,
    for SSSP, per-round semantics are the intended unit) — one pass."""
    tag = f"{arch}__{shape}__{'multipod' if multi_pod else 'singlepod'}"
    path = os.path.join(out_dir, tag + ".json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)

    mesh = make_production_mesh(multi_pod=multi_pod)
    ax = mesh_axes(multi_pod)
    family = "sssp" if arch in ("sp-async", "sssp") else ARCH_FAMILY[arch]
    rec = dict(arch=arch, shape=shape, multi_pod=multi_pod,
               n_devices=mesh.size, status="ok")
    t0 = time.time()
    try:
        cell = build_cell(arch, shape, mesh, ax)
        if cell.skip:
            rec.update(status="skipped", reason=cell.skip)
        else:
            # LM cells: collectives inside the layer-scan body are scaled
            # by n_layers (cost/collectives of a while body count once)
            loop_scale = 1
            if family == "lm":
                from repro.configs.registry import _load
                loop_scale = _load(arch)[1].n_layers
            m1 = _compile_and_measure(cell, mesh, loop_scale=loop_scale)
            rec.update(kind=cell.kind, note=cell.note, model_flops=cell.model_flops,
                       lower_s=m1["lower_s"], compile_s=m1["compile_s"],
                       memory=m1["memory"], collectives=m1["collectives"])
            if family == "lm" and flops_pass:
                # honest FLOP totals: unrolled module, lower-only (no XLA opt)
                cell2 = build_cell(arch, shape, mesh, ax, scan_layers=False)
                m2 = _lower_cost_only(cell2, mesh)
                rec.update(hlo_flops=m2["hlo_flops"], hlo_bytes=m2["hlo_bytes"],
                           flops_pass=dict(lower_s=m2["lower_s"], mode="lower-only"))
            else:
                rec.update(hlo_flops=m1["hlo_flops"], hlo_bytes=m1["hlo_bytes"])
            rec["roofline"] = roofline_terms(
                rec["hlo_flops"], rec["hlo_bytes"],
                rec["collectives"]["total"], mesh.size, cell.model_flops)
            t = rec["roofline"]
            print(f"[{tag}] mem/device: args={_gb(rec['memory']['argument_bytes'])} "
                  f"temp={_gb(rec['memory']['temp_bytes'])} | "
                  f"flops={rec['hlo_flops']:.3e} bytes={rec['hlo_bytes']:.3e} "
                  f"coll={rec['collectives']['total']:.3e} "
                  f"dominant={t['dominant']} useful={t['useful_ratio']:.2f}")
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
        print(f"[{tag}] ERROR {type(e).__name__}: {e}")
    rec["wall_s"] = round(time.time() - t0, 2)
    os.makedirs(out_dir, exist_ok=True)
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def _gb(b):
    return f"{b / 2**30:.2f}GiB" if isinstance(b, (int, float)) else "?"


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch")
    p.add_argument("--shape")
    p.add_argument("--all", action="store_true")
    p.add_argument("--multi-pod", action="store_true")
    p.add_argument("--both-meshes", action="store_true")
    p.add_argument("--force", action="store_true")
    p.add_argument("--out", default=os.path.abspath(ARTIFACT_DIR))
    args = p.parse_args()

    cells = list_cells() if args.all else [(args.arch, args.shape)]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    n_ok = n_skip = n_err = 0
    for arch, shape in cells:
        for mp in meshes:
            # multi-pod pass proves the pod axis shards (memory+compile);
            # FLOP totals come from the single-pod unrolled pass
            rec = run_cell(arch, shape, mp, args.out, force=args.force,
                           flops_pass=not mp)
            s = rec["status"]
            n_ok += s == "ok"
            n_skip += s == "skipped"
            n_err += s == "error"
    print(f"dry-run done: ok={n_ok} skipped={n_skip} errors={n_err}")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
