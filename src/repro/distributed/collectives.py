"""Collective helpers used inside ``shard_map`` bodies.

All helpers take ``axis_names`` — a tuple of mesh axis names over which the
logical 1-D partition axis is flattened (e.g. ``("data", "model")`` for the
single-pod 16×16 mesh, ``("pod", "data", "model")`` multi-pod). Ranks follow
row-major order over those axes, so ``flat_rank`` is consistent with how a
``[P, ...]``-leading array is laid out by ``shard_map`` in_specs.
"""
from __future__ import annotations

from functools import reduce

import jax
import jax.numpy as jnp
from jax import lax


def _axis_size(a) -> int:
    if hasattr(lax, "axis_size"):          # jax >= 0.5
        return lax.axis_size(a)
    from jax._src import core
    frame = core.axis_frame(a)
    return frame if isinstance(frame, int) else frame.size


def axis_sizes(axis_names) -> tuple[int, ...]:
    return tuple(_axis_size(a) for a in axis_names)


def flat_rank(axis_names) -> jax.Array:
    """Row-major flattened rank over the given mesh axes."""
    r = jnp.int32(0)
    for a in axis_names:
        r = r * _axis_size(a) + lax.axis_index(a)
    return r


def flat_size(axis_names) -> int:
    return int(reduce(lambda x, y: x * y, axis_sizes(axis_names), 1))


def pmin_named(x, axis_names):
    return lax.pmin(x, axis_names)


def pmax_named(x, axis_names):
    return lax.pmax(x, axis_names)


def psum_named(x, axis_names):
    return lax.psum(x, axis_names)


def all_reduce_min(x, axis_names):
    return lax.pmin(x, axis_names)


def or_reduce(flag, axis_names):
    """Logical OR across shards (any)."""
    return lax.pmax(flag.astype(jnp.int32), axis_names) > 0


def and_reduce(flag, axis_names):
    """Logical AND across shards (all)."""
    return lax.pmin(flag.astype(jnp.int32), axis_names) > 0


def all_to_all_tiled(x, axis_names):
    """all_to_all where dim 0 of ``x`` is the (flattened) partition dim.

    x: [P, ...] per shard → returns [P, ...] where row p came from shard p's
    row ``self``. Works over a tuple of axis names (XLA flattens them in
    row-major order, matching ``flat_rank``).
    """
    return lax.all_to_all(x, axis_names, split_axis=0, concat_axis=0, tiled=True)


def ring_permute(x, axis_names):
    """Advance ``x`` one hop along the row-major ring over ``axis_names``.

    After the call, the value previously held by rank r lives on rank
    (r + 1) mod P. This is the literal token-ring transport for ToKa2 —
    on TPU it lowers to collective-permutes over the ICI.

    Implementation: a +1 shift on the last axis, with carry shifts on the
    earlier axes applied only to ranks whose lower-order indices wrapped to
    zero (i.e. the carry positions).
    """
    return _ring_shift(x, axis_names, step=1)


def ring_permute_rev(x, axis_names):
    """Retreat ``x`` one hop along the row-major ring: the value previously
    held by rank r lives on rank (r - 1) mod P afterwards. The backward
    direction of the bidirectional ``async_ppermute`` transport — routing a
    message the short way around the ring halves its worst-case delivery
    lag versus a single forward ring."""
    return _ring_shift(x, axis_names, step=-1)


def _ring_shift(x, axis_names, step: int):
    names = tuple(axis_names)
    sizes = axis_sizes(names)

    def shift(v, name, size):
        perm = [(i, (i + step) % size) for i in range(size)]
        return lax.ppermute(v, name, perm)

    # shift along the last axis; values that wrapped (arrived at index 0
    # going forward, index size-1 going backward) must additionally be
    # shifted along the next-more-significant axis, cascading leftward.
    wrap_to = (lambda size: 0) if step > 0 else (lambda size: size - 1)
    y = shift(x, names[-1], sizes[-1])
    carry_mask = lax.axis_index(names[-1]) == wrap_to(sizes[-1])
    for k in range(len(names) - 2, -1, -1):
        y_carry = shift(y, names[k], sizes[k])
        y = jax.tree_util.tree_map(
            lambda a, b: jnp.where(carry_mask, b, a), y, y_carry)
        carry_mask = carry_mask & (lax.axis_index(names[k]) == wrap_to(sizes[k]))
    return y
