"""Mesh-axis conventions shared by all architectures.

Single-pod mesh: (data=16, model=16). Multi-pod: (pod=2, data=16, model=16)
— the pod axis joins the data/FSDP group (pure DP across pods keeps
cross-pod traffic to one gradient all-reduce per step, the right choice
when inter-pod links are the scarce resource).
"""
from __future__ import annotations

import dataclasses

from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class MeshAxes:
    data: tuple          # axes carrying batch/FSDP shards, e.g. ("pod","data")
    model: str = "model"
    data_shards: int = 1  # product of data-axis sizes (static hierarchy hint
                          # for shard-local algorithms, e.g. MoE dispatch)

    @property
    def all(self):
        return (*self.data, self.model)

    # common activation/param specs
    def batch(self, *rest):
        return P(self.data, *rest)

    def fsdp_tp(self, *, prefix=()):
        """[..., fsdp_dim, tp_dim] param spec."""
        return P(*prefix, self.data, self.model)


SINGLE_POD = MeshAxes(data=("data",), data_shards=16)
MULTI_POD = MeshAxes(data=("pod", "data"), data_shards=32)


def mesh_axes(multi_pod: bool) -> MeshAxes:
    return MULTI_POD if multi_pod else SINGLE_POD
