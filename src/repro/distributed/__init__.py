from repro.distributed.collectives import (
    ring_permute, flat_rank, all_to_all_tiled, pmin_named, pmax_named, psum_named,
    all_reduce_min, and_reduce, or_reduce,
)
