"""LR schedules (pure functions of step)."""
from __future__ import annotations

import jax.numpy as jnp


def cosine_schedule(step, total_steps: int, min_ratio: float = 0.1):
    frac = jnp.clip(step.astype(jnp.float32) / max(total_steps, 1), 0.0, 1.0)
    return min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * frac))


def linear_warmup_cosine(step, warmup: int, total_steps: int,
                         min_ratio: float = 0.1):
    s = step.astype(jnp.float32)
    w = jnp.clip(s / max(warmup, 1), 0.0, 1.0)
    return w * cosine_schedule(jnp.maximum(s - warmup, 0.0),
                               max(total_steps - warmup, 1), min_ratio)
