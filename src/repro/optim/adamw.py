"""Hand-rolled AdamW (no optax dependency) with global-norm clipping.

Optimizer state mirrors the parameter tree, so the same PartitionSpecs
shard it (m/v inherit the param's spec — states are sharded wherever the
params are, which with FSDP-style specs is ZeRO-equivalent).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


class AdamWState(NamedTuple):
    step: jax.Array
    m: object
    v: object


def adamw_init(params) -> AdamWState:
    zeros = jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros,
                      v=jax.tree_util.tree_map(jnp.copy, zeros))


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-12))
    return jax.tree_util.tree_map(lambda g: g * scale, tree), norm


def adamw_update(params, grads, state: AdamWState, cfg: AdamWConfig,
                 lr_scale=1.0):
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - cfg.b1 ** t
    bc2 = 1.0 - cfg.b2 ** t
    lr = cfg.lr * lr_scale

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m2 = cfg.b1 * m + (1 - cfg.b1) * g32
        v2 = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g32)
        mh = m2 / bc1
        vh = v2 / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m2, v2

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(state.m)
    flat_v = jax.tree_util.tree_leaves(state.v)
    new = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(tdef, [x[0] for x in new])
    new_m = jax.tree_util.tree_unflatten(tdef, [x[1] for x in new])
    new_v = jax.tree_util.tree_unflatten(tdef, [x[2] for x in new])
    return new_p, AdamWState(step=step, m=new_m, v=new_v), gnorm
