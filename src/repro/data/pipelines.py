"""Synthetic data pipelines (deterministic, host-side, double-buffered).

Real deployments swap the generators for file readers; the batching,
prefetch, and device-put seams are what the training loop depends on.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp


def synthetic_lm_batch(rng: np.random.Generator, batch: int, seq: int,
                       vocab: int):
    """Markov-ish token stream: next-token structure so loss can fall."""
    base = rng.integers(0, vocab, (batch, seq + 1))
    # inject copy structure: 50% of positions repeat t-1 (learnable signal)
    rep = rng.random((batch, seq)) < 0.5
    base[:, 1:][rep] = base[:, :-1][rep]
    return {"tokens": jnp.asarray(base[:, :-1], jnp.int32),
            "labels": jnp.asarray(base[:, 1:], jnp.int32)}


class TokenStream:
    def __init__(self, batch: int, seq: int, vocab: int, seed: int = 0):
        self.rng = np.random.default_rng(seed)
        self.batch, self.seq, self.vocab = batch, seq, vocab

    def __iter__(self):
        return self

    def __next__(self):
        return synthetic_lm_batch(self.rng, self.batch, self.seq, self.vocab)


class GraphBatcher:
    """Full-graph batches or sampler-driven minibatches for the GNN archs."""

    def __init__(self, batch_builder, steps: int | None = None):
        self.batch_builder = batch_builder
        self.steps = steps
        self._i = 0

    def __iter__(self):
        return self

    def __next__(self):
        if self.steps is not None and self._i >= self.steps:
            raise StopIteration
        self._i += 1
        return self.batch_builder(self._i)


class RecsysBatcher:
    def __init__(self, batch: int, n_fields: int, vocab_per_field: int,
                 multi_hot: int = 1, seed: int = 0):
        self.rng = np.random.default_rng(seed)
        self.batch, self.F, self.V, self.L = batch, n_fields, vocab_per_field, multi_hot

    def __next__(self):
        # skewed (zipf-ish) ids — embedding-access realism
        raw = self.rng.zipf(1.2, (self.batch, self.F, self.L)) % self.V
        field_off = (np.arange(self.F) * self.V)[None, :, None]
        idx = raw + field_off
        # synthetic label correlated with low ids (learnable)
        y = (raw[:, :, 0].sum(1) % 2).astype(np.int32)
        return {"sparse_idx": jnp.asarray(idx, jnp.int32),
                "labels": jnp.asarray(y, jnp.int32)}

    def __iter__(self):
        return self
