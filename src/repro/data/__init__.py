from repro.data.pipelines import (
    TokenStream, GraphBatcher, RecsysBatcher, synthetic_lm_batch)
