"""Pallas TPU flash attention (FlashAttention-2 schedule, GQA-aware).

Grid ``(B, Hq, nq, nkv)``; the kv axis is innermost so the (q-tile ×
head) output block and the f32 accumulators persist in VMEM scratch across
kv steps (online softmax). GQA is resolved in the k/v BlockSpec index maps
(query head h reads kv head ``h // group``) — no repeated-KV materialization.

VMEM per step: q (BQ×D), k/v (BK×D each), acc (BQ×D f32), s/p (BQ×BK f32).
With BQ=BK=512, D=128: ~2.5 MiB — comfortably inside 16 MiB v5e VMEM and
big enough to keep the MXU busy (512×128 × 128×512 matmuls).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, causal: bool, q_offset: int, kv_len: int,
                  block_q: int, block_k: int):
    i = pl.program_id(2)          # q tile
    j = pl.program_id(3)          # kv tile
    nkv = pl.num_programs(3)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)          # [BQ, D]
    k = k_ref[0, 0].astype(jnp.float32)          # [BK, D]
    v = v_ref[0, 0].astype(jnp.float32)          # [BK, D]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale  # [BQ, BK]

    kj = j * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    valid = kj < kv_len                           # mask kv padding
    if causal:
        qi = (i * block_q + q_offset
              + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0))
        valid = valid & (qi >= kj)
    s = jnp.where(valid, s, -jnp.inf)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    # rows with everything masked keep m = -inf; exp(-inf - -inf) guards below
    p = jnp.exp(s - jnp.where(jnp.isfinite(m_new), m_new, 0.0)[:, None])
    p = jnp.where(valid, p, 0.0)
    alpha = jnp.exp(jnp.where(jnp.isfinite(m_prev), m_prev - m_new, -jnp.inf))
    alpha = jnp.where(jnp.isfinite(m_prev), alpha, 0.0)

    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot(p, v)
    m_ref[...] = m_new

    @pl.when(j == nkv - 1)
    def _finalize():
        l = l_ref[...]
        denom = jnp.where(l > 0, l, 1.0)
        o_ref[0, 0] = (acc_ref[...] / denom[:, None]).astype(o_ref.dtype)


def flash_attention_p(q, k, v, *, scale: float, causal: bool, q_offset: int,
                      kv_len: int, block_q: int, block_k: int,
                      interpret: bool = True):
    """q: [B, Hq, Sq_pad, D]; k/v: [B, Hkv, Skv_pad, D] (pre-padded)."""
    B, Hq, Sq, D = q.shape
    _, Hkv, Skv, _ = k.shape
    group = Hq // Hkv
    nq, nkv = Sq // block_q, Skv // block_k
    grid = (B, Hq, nq, nkv)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, q_offset=q_offset,
        kv_len=kv_len, block_q=block_q, block_k=block_k)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, i, j, g=group: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, i, j, g=group: (b, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, D), jnp.float32),   # acc
            pltpu.VMEM((block_q,), jnp.float32),     # m (running max)
            pltpu.VMEM((block_q,), jnp.float32),     # l (running denom)
        ],
        interpret=interpret,
    )(q, k, v)
