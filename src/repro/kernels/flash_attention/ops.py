"""jit'd public wrapper: padding, GQA checks, decode offsets."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import flash_attention_p


def _pad_seq(x, block, axis):
    s = x.shape[axis]
    pad = (-s) % block
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@partial(jax.jit, static_argnames=("causal", "scale", "q_offset", "block_q",
                                   "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, scale: float | None = None,
                    q_offset: int = 0, block_q: int = 128, block_k: int = 128,
                    interpret: bool = True):
    """q: [B, Hq, Sq, D]; k/v: [B, Hkv, Skv, D]. Returns [B, Hq, Sq, D].

    ``q_offset`` positions queries for causal decode (q_offset = Skv - Sq)."""
    B, Hq, Sq, D = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    assert Hq % Hkv == 0, (Hq, Hkv)
    if scale is None:
        scale = float(D) ** -0.5

    bq = min(block_q, max(Sq, 1))
    bk = min(block_k, max(Skv, 1))
    qp = _pad_seq(q, bq, 2)
    kp = _pad_seq(k, bk, 2)
    vp = _pad_seq(v, bk, 2)
    out = flash_attention_p(qp, kp, vp, scale=scale, causal=causal,
                            q_offset=q_offset, kv_len=Skv, block_q=bq,
                            block_k=bk, interpret=interpret)
    return out[:, :, :Sq, :]
