"""Pure-jnp oracle: softmax attention with GQA + optional causal mask."""
from __future__ import annotations

import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True, scale: float | None = None,
                  q_offset: int = 0):
    """q: [B, Hq, Sq, D]; k/v: [B, Hkv, Skv, D]. Hq % Hkv == 0.

    ``q_offset``: absolute position of q[0] (decode: Skv - Sq)."""
    B, Hq, Sq, D = q.shape
    Hkv = k.shape[1]
    group = Hq // Hkv
    if scale is None:
        scale = D ** -0.5
    kk = jnp.repeat(k, group, axis=1)
    vv = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kk.astype(jnp.float32)) * scale
    if causal:
        qi = jnp.arange(Sq)[:, None] + q_offset
        kj = jnp.arange(k.shape[2])[None, :]
        s = jnp.where(qi >= kj, s, -jnp.inf)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vv.astype(jnp.float32))
    return out.astype(q.dtype)
