"""Pure-jnp oracle for the send-phase segment-min pack.

Per query: slot_val[s] = min over cut edges e with seg[e] == s of
(dist[src[e]] + w[e]); only improvements over last_sent transmit.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

INF = jnp.float32(jnp.inf)


def send_pack_ref(dist, cut_src, cut_w, cut_seg, n_slots, slot_valid,
                  last_sent):
    """dist: [K, block]; cut_src/cut_w/cut_seg: [e_cut] (seg sorted,
    padding w = +inf); slot_valid: [S] bool; last_sent: [K, S].
    Returns (send_val [K, S] — INF where not improved, new_last [K, S],
    sends [K] i32)."""
    d_src = jnp.take(dist, cut_src, axis=1, mode="fill",
                     fill_value=float("inf"))
    cand = d_src + cut_w
    slot_val = jax.vmap(lambda c: jax.ops.segment_min(
        c, cut_seg, num_segments=n_slots, indices_are_sorted=True))(cand)
    improved = slot_valid & (slot_val < last_sent)
    send_val = jnp.where(improved, slot_val, INF)
    new_last = jnp.where(improved, slot_val, last_sent)
    sends = jnp.sum(improved, axis=-1).astype(jnp.int32)
    return send_val, new_last, sends
