"""jit'd wrappers + host-side slot-tiled layout builder for the send kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.relax import build_dst_ragged_layout, build_dst_tiled_layout
from repro.kernels.send.send import send_pack_ragged, send_pack_tiled

INF = float("inf")


def build_slot_ragged_layout(cut_src, cut_seg, cut_w, n_slots: int, *,
                             sb: int = 128, eb: int = 512):
    """Ragged (CSR-chunked) slot layout: cut edges -> flat [total_chunks,
    EB] rows + [total_chunks] chunk→tile map, same slot-in-destination-role
    reuse of the relax builder as ``build_slot_tiled_layout`` (padding
    sources restamped to 0 — in range, inert via +inf weight).

    Returns (src_r, w_r, segrel_r, eid_r, ctile, S_pad)."""
    src_r, w_r, segrel_r, eid_r, ctile, s_pad = build_dst_ragged_layout(
        cut_src, cut_seg, cut_w, n_slots, vb=sb, eb=eb, with_eid=True)
    pad = eid_r == len(np.asarray(cut_src))
    src_r = jnp.where(pad, 0, src_r)
    return src_r, w_r, segrel_r, eid_r, ctile, s_pad


def build_slot_tiled_layout(cut_src, cut_seg, cut_w, n_slots: int, *,
                            sb: int = 128, eb: int = 512):
    """One-time host preprocessing: cut edges -> [n_stiles, n_chunks, EB]
    grouped by message-slot tile.

    Structurally the dst-tiled relax layout with the SLOT id in the
    destination role, so the same builder is reused; the one difference is
    the padding-source sentinel: the relax layout points padding at the
    padded DISTANCE slot (``block_pad - 1``), but here the gather target is
    the distance row while the tiling target is the slot axis, so padding
    entries are restamped to source 0 (any in-range vertex — their +inf
    weight keeps them inert).

    Returns (src_t, w_t, segrel_t, eid_t, S_pad); eid_t maps tiled slots
    back to positions in the ORIGINAL cut-edge list (sentinel = len(cut_src))
    so the runtime Trishla pruned mask gathers into tiled order.
    """
    src_t, w_t, segrel_t, eid_t, s_pad = build_dst_tiled_layout(
        cut_src, cut_seg, cut_w, n_slots, vb=sb, eb=eb, with_eid=True)
    pad = eid_t == len(np.asarray(cut_src))
    src_t = jnp.where(pad, 0, src_t)
    return src_t, w_t, segrel_t, eid_t, s_pad


@partial(jax.jit, static_argnames=("sb", "eb", "interpret"))
def send_pack_pallas(dist, last_sent, slot_valid, src_t, w_t, segrel_t,
                     pruned_t, ctile=None, *, sb: int = 128, eb: int = 512,
                     interpret: bool = True):
    """Solver-facing wrapper: pads to kernel tile shapes, slices back.

    dist: [K, block]; last_sent: [K, S]; slot_valid: [S] bool;
    src_t/w_t/segrel_t/pruned_t: [n_stiles, n_chunks, EB] slot-tiled layout
    (pruned_t already gathered into tiled order), or — with ``ctile`` given
    — flat [total_chunks, EB] ragged rows plus the chunk→tile map. Returns
    (send_val [K, S] — INF where not improved, new_last [K, S], sends [K]).
    """
    nq, block = dist.shape
    S = last_sent.shape[1]
    n_stiles = src_t.shape[0] if ctile is None else max(-(-S // sb), 1)
    sp = n_stiles * sb
    bp = -(-block // 128) * 128      # lane-align the gathered distance row
    dist_pad = jnp.full((nq, bp), INF).at[:, :block].set(dist)
    last_pad = jnp.full((nq, sp), INF).at[:, :S].set(last_sent)
    valid_pad = jnp.zeros((sp,), jnp.int32).at[:S].set(
        slot_valid.astype(jnp.int32))
    if ctile is None:
        val, new_last, sends = send_pack_tiled(
            dist_pad, last_pad, valid_pad, src_t, w_t, segrel_t, pruned_t,
            sb=sb, eb=eb, interpret=interpret)
    else:
        val, new_last, sends = send_pack_ragged(
            dist_pad, last_pad, valid_pad, ctile, src_t, w_t, segrel_t,
            pruned_t, sb=sb, eb=eb, interpret=interpret)
    return val[:, :S], new_last[:, :S], sends


def send_payload_bucket(send_val, payload_slot):
    """Route masked slot values into the [K, P, C] bucketed payload.

    ``payload_slot[p, c]`` is the STATIC inverse of ``(slot_owner,
    slot_pos)``: the slot feeding position ``c`` of the row bound for shard
    ``p`` (sentinel = out-of-range -> INF). Because each payload position
    receives at most one slot, the runtime scatter the XLA path pays
    becomes a plain gather."""
    return jnp.take(send_val, payload_slot.reshape(-1), axis=1, mode="fill",
                    fill_value=INF).reshape(
                        send_val.shape[0], *payload_slot.shape)
