"""Pallas TPU kernel: send-phase segment-min pack (SP-Async boundary send).

The send phase reduces every shard's cut-edge candidates ``dist[src] + w``
to ONE value per message slot (a slot = a unique boundary pair
``(dst_owner, dst_local)``), masks the result against ``last_sent`` so only
improvements transmit, and counts the sends. The XLA realization is a
``segment_min`` — a sorted scatter with no efficient TPU lowering (the
same gap the relax kernel closed for the local phase).

TPU adaptation, following ``kernels/relax``'s dst-tiled pattern with the
SLOT axis in the destination role: cut edges are pre-grouped by slot tile
(host-side, one-time — the grouping is as static as the message routing
itself) into ``[n_stiles, n_chunks, EB]`` arrays, and each grid step
produces one SB-wide slot tile via the one-hot masked min-reduce (pure VPU
work). The source-distance gather is the same 1-D dynamic gather from the
VMEM-resident distance row the relax kernel uses.

Grid ``(n_stiles, n_chunks)`` — NO query axis. Each edge chunk is fetched
exactly once and all K queries reduce against it in-register via the
batched one-hot reduce (``tile_min_batch``), the same layout-amortization
the batched relax kernel proves: layout tile loads per round are
``n_tiles``, not ``n_tiles × K``. Because the grid iterates chunks within
a tile, all chunks of tile ``i`` are complete at ``j == n_chunks - 1``, so
the improvement mask against ``last_sent``, the ``last_sent`` update, and
the per-query send counts all happen in-kernel at tile finalization — the
kernel emits exactly what the solver's send phase needs, not a partial
reduction.

VMEM working set per step:
  dist rows                 4 * K * block_pad
  last_sent / send_val / new_last rows   12 * K * S_pad
  edge chunk (src, w, segrel, pruned)    ~16 * EB
  one-hot expansion         4 * K * EB * SB   (dominant; batched reduce)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.tile_reduce import tile_min_batch

INF = float("inf")


def _send_pack_kernel(dist_ref, last_ref, valid_ref, src_ref, w_ref,
                      segrel_ref, pruned_ref, val_ref, newlast_ref, sends_ref,
                      count_ref, *, sb: int, n_stiles: int, n_chunks: int,
                      n_queries: int):
    """Grid (slot tile i, edge chunk j) — whole query batch per step.

    ``val_ref`` accumulates raw per-slot minima for ALL K queries while
    tile ``i`` streams its chunks; at the tile's last chunk it is rewritten
    in place as the masked send value (INF where no improvement) and
    ``newlast_ref`` / ``count_ref`` are updated. SMEM ``count_ref`` holds
    the per-query send counters."""
    i = pl.program_id(0)
    j = pl.program_id(1)
    first = (i == 0) & (j == 0)
    last = (i == n_stiles - 1) & (j == n_chunks - 1)
    tile = pl.dslice(i * sb, sb)

    @pl.when(first)
    def _init_counts():
        for k in range(n_queries):
            count_ref[k] = 0

    @pl.when(j == 0)
    def _init_tile():
        val_ref[:, tile] = jnp.full((n_queries, sb), INF, jnp.float32)

    # accumulate this chunk's candidates into the slot tile, all queries
    src = src_ref[0, 0, :]                    # [EB] int32 (padding = 0)
    w = jnp.where(pruned_ref[0, 0, :] > 0, INF, w_ref[0, 0, :])
    segrel = segrel_ref[0, 0, :]              # [EB] int32 in [0, sb)
    d_src = jnp.take(dist_ref[...], src, axis=1)      # [K, EB]
    cand = d_src + w[None, :]
    mins = tile_min_batch(cand, segrel, width=sb)     # [K, sb]
    val_ref[:, tile] = jnp.minimum(val_ref[:, tile], mins)

    # tile i complete: improvement mask + last_sent update + counts
    @pl.when(j == n_chunks - 1)
    def _finalize_tile():
        val = val_ref[:, tile]                        # [K, sb]
        prev = last_ref[:, tile]
        valid = valid_ref[tile][None, :] > 0
        improved = valid & (val < prev)
        val_ref[:, tile] = jnp.where(improved, val, INF)
        newlast_ref[:, tile] = jnp.where(improved, val, prev)
        sums = jnp.sum(improved, axis=1).astype(jnp.int32)
        for k in range(n_queries):
            count_ref[k] = count_ref[k] + sums[k]

    @pl.when(last)
    def _fin():
        for k in range(n_queries):
            sends_ref[k] = count_ref[k]


def send_pack_tiled(dist_pad, last_pad, valid_pad, src_t, w_t, segrel_t,
                    pruned_t, *, sb: int, eb: int, interpret: bool = True):
    """dist_pad: [K, block_pad] f32; last_pad/valid_pad: [K, S_pad] /
    [S_pad] with S_pad = n_stiles * sb; src_t/w_t/segrel_t/pruned_t:
    [n_stiles, n_chunks, EB] slot-tiled cut-edge layout (shared by all K
    queries). Returns (send_val [K, S_pad] — INF where not improved,
    new_last [K, S_pad], sends [K] i32)."""
    n_stiles, n_chunks, eb_l = src_t.shape
    nq, bp = dist_pad.shape
    sp = n_stiles * sb
    assert eb_l == eb and last_pad.shape == (nq, sp)
    assert valid_pad.shape == (sp,)

    grid = (n_stiles, n_chunks)
    dist_spec = pl.BlockSpec((nq, bp), lambda i, j: (0, 0))
    slot_spec = pl.BlockSpec((nq, sp), lambda i, j: (0, 0))
    edge_spec = pl.BlockSpec((1, 1, eb), lambda i, j: (i, j, 0))
    kernel = functools.partial(_send_pack_kernel, sb=sb, n_stiles=n_stiles,
                               n_chunks=n_chunks, n_queries=nq)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            dist_spec,
            slot_spec,
            pl.BlockSpec((sp,), lambda i, j: (0,)),
            edge_spec, edge_spec, edge_spec, edge_spec,
        ],
        out_specs=[
            slot_spec,                                     # masked send values
            slot_spec,                                     # updated last_sent
            pl.BlockSpec((nq,), lambda i, j: (0,)),        # per-query sends
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nq, sp), jnp.float32),
            jax.ShapeDtypeStruct((nq, sp), jnp.float32),
            jax.ShapeDtypeStruct((nq,), jnp.int32),
        ],
        scratch_shapes=[pltpu.SMEM((nq,), jnp.int32)],
        interpret=interpret,
    )(dist_pad, last_pad, valid_pad, src_t, w_t, segrel_t, pruned_t)


def _send_pack_ragged_kernel(ctile_ref, dist_ref, last_ref, valid_ref,
                             src_ref, w_ref, segrel_ref, pruned_ref, val_ref,
                             newlast_ref, sends_ref, *, sb: int,
                             n_stiles: int, total_chunks: int,
                             n_queries: int):
    """Ragged grid ``(total_chunks,)``: each flat chunk carries its slot
    tile in the scalar-prefetched ``ctile`` map. Init/finalize move from
    per-tile to GLOBAL (whole [K, S_pad] at the first/last chunk): the
    accumulate step never reads the improvement mask, so finalizing every
    tile at once — after all its chunks necessarily streamed — produces
    bit-identical send values, and zero-chunk tiles (absent from the ragged
    chunk list entirely) still get their INF/no-improvement finalization."""
    c = pl.program_id(0)
    t = jnp.minimum(ctile_ref[c], n_stiles - 1)
    tile = pl.dslice(t * sb, sb)

    @pl.when(c == 0)
    def _init():
        val_ref[...] = jnp.full(val_ref.shape, INF, jnp.float32)

    src = src_ref[0, :]                       # [EB] int32 (padding = 0)
    w = jnp.where(pruned_ref[0, :] > 0, INF, w_ref[0, :])
    segrel = segrel_ref[0, :]                 # [EB] int32 in [0, sb)
    d_src = jnp.take(dist_ref[...], src, axis=1)      # [K, EB]
    cand = d_src + w[None, :]
    mins = tile_min_batch(cand, segrel, width=sb)     # [K, sb]
    val_ref[:, tile] = jnp.minimum(val_ref[:, tile], mins)

    @pl.when(c == total_chunks - 1)
    def _fin():
        val = val_ref[...]                            # [K, S_pad]
        prev = last_ref[...]
        valid = valid_ref[...][None, :] > 0
        improved = valid & (val < prev)
        val_ref[...] = jnp.where(improved, val, INF)
        newlast_ref[...] = jnp.where(improved, val, prev)
        sums = jnp.sum(improved, axis=1).astype(jnp.int32)
        for k in range(n_queries):
            sends_ref[k] = sums[k]


def send_pack_ragged(dist_pad, last_pad, valid_pad, ctile, src_r, w_r,
                     segrel_r, pruned_r, *, sb: int, eb: int,
                     interpret: bool = True):
    """Ragged counterpart of ``send_pack_tiled``: the slot-tiled layout is
    flat [total_chunks, EB] rows plus the [total_chunks] chunk→tile map
    (sentinel ``n_stiles`` marks inert padding chunks, clamped in-kernel).
    ``S_pad`` comes from ``last_pad`` since the layout no longer encodes the
    tile count. Same returns as the dense kernel."""
    total_chunks, eb_l = src_r.shape
    nq, bp = dist_pad.shape
    sp = last_pad.shape[1]
    assert eb_l == eb and sp % sb == 0
    assert valid_pad.shape == (sp,)
    n_stiles = sp // sb

    grid = (total_chunks,)
    dist_spec = pl.BlockSpec((nq, bp), lambda c, ctile: (0, 0))
    slot_spec = pl.BlockSpec((nq, sp), lambda c, ctile: (0, 0))
    edge_spec = pl.BlockSpec((1, eb), lambda c, ctile: (c, 0))
    kernel = functools.partial(_send_pack_ragged_kernel, sb=sb,
                               n_stiles=n_stiles, total_chunks=total_chunks,
                               n_queries=nq)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            dist_spec,
            slot_spec,
            pl.BlockSpec((sp,), lambda c, ctile: (0,)),
            edge_spec, edge_spec, edge_spec, edge_spec,
        ],
        out_specs=[
            slot_spec,                                     # masked send values
            slot_spec,                                     # updated last_sent
            pl.BlockSpec((nq,), lambda c, ctile: (0,)),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((nq, sp), jnp.float32),
            jax.ShapeDtypeStruct((nq, sp), jnp.float32),
            jax.ShapeDtypeStruct((nq,), jnp.int32),
        ],
        interpret=interpret,
    )(ctile, dist_pad, last_pad, valid_pad, src_r, w_r, segrel_r, pruned_r)
