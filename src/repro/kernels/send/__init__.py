from repro.kernels.send.ops import (
    build_slot_ragged_layout, build_slot_tiled_layout, send_pack_pallas,
    send_payload_bucket,
)
from repro.kernels.send.ref import send_pack_ref
