from repro.kernels.relax.ops import relax_pallas, relax_jnp, build_dst_tiled_layout
from repro.kernels.relax.ref import relax_ref
