from repro.kernels.relax.ops import (
    build_dst_ragged_layout, build_dst_tiled_layout,
    relax_fixpoint_batch_pallas, relax_fixpoint_batch_ragged_pallas,
    relax_fixpoint_pallas, relax_jnp, relax_masked_pallas, relax_pallas,
)
from repro.kernels.relax.ref import relax_ref
