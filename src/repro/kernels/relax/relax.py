"""Pallas TPU kernel: blocked min-plus edge relaxation (SP-Async hot loop).

TPU adaptation (vs. the CUDA-style atomicMin scatter a GPU port would use):
scatter has no efficient TPU lowering, so edges are *pre-tiled by
destination* (host-side, one-time — the layout is as static as the CSR
itself) and each grid step produces one VB-wide vertex tile with a one-hot
masked min-reduce, which is pure VPU work over an [EB, VB] tile held in
VMEM. The source-distance gather is a 1-D dynamic gather from the
VMEM-resident distance vector (Mosaic ``DynamicGatherOp``; validated here
in interpret mode since the container is CPU-only).

Grid: ``(n_vtiles, n_chunks)`` — the chunk axis streams over a tile's edge
list in EB-sized pieces, revisiting the same output block (reduction
pattern; initialized at chunk 0).

VMEM working set per step:
  dist (full block)            4 * block_pad
  edge chunk (src, w, dstrel)  ~12 * EB
  one-hot tile                 4 * EB * VB   (dominant; 512*128*4 = 256 KiB)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INF = jnp.float32(jnp.inf)


def _relax_kernel(dist_ref, src_ref, w_ref, dstrel_ref, out_ref, *, vb: int):
    i = pl.program_id(0)   # vertex tile
    j = pl.program_id(1)   # edge chunk within the tile

    # initialize the output tile from the current distances on first visit
    @pl.when(j == 0)
    def _init():
        out_ref[...] = dist_ref[pl.dslice(i * vb, vb)]

    src = src_ref[0, 0, :]                 # [EB] int32 (sentinel = block_pad-1)
    w = w_ref[0, 0, :]                     # [EB] f32 (+inf padding)
    dstrel = dstrel_ref[0, 0, :]           # [EB] int32 in [0, vb)

    d_src = jnp.take(dist_ref[...], src)   # 1-D dynamic gather from VMEM
    cand = d_src + w                       # [EB]

    eb = cand.shape[0]
    lane = jax.lax.broadcasted_iota(jnp.int32, (eb, vb), 1)
    onehot = dstrel[:, None] == lane       # [EB, VB]
    mins = jnp.min(jnp.where(onehot, cand[:, None], jnp.float32(float("inf"))), axis=0)
    out_ref[...] = jnp.minimum(out_ref[...], mins)


def relax_dst_tiled(dist_pad, src_t, w_t, dstrel_t, *, vb: int, eb: int,
                    interpret: bool = True):
    """dist_pad: [block_pad] f32 (block_pad % vb == 0).
    src_t/w_t/dstrel_t: [n_vtiles, n_chunks, EB] dst-tiled edge layout.
    Returns new distances [block_pad]."""
    n_vtiles, n_chunks, eb_l = src_t.shape
    assert eb_l == eb and dist_pad.shape[0] == n_vtiles * vb

    grid = (n_vtiles, n_chunks)
    kernel = functools.partial(_relax_kernel, vb=vb)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(dist_pad.shape, lambda i, j: (0,)),          # full dist
            pl.BlockSpec((1, 1, eb), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, 1, eb), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, 1, eb), lambda i, j: (i, j, 0)),
        ],
        out_specs=pl.BlockSpec((vb,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((n_vtiles * vb,), dist_pad.dtype),
        interpret=interpret,
    )(dist_pad, src_t, w_t, dstrel_t)
