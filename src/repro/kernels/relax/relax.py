"""Pallas TPU kernels: blocked min-plus edge relaxation (SP-Async hot loop).

TPU adaptation (vs. the CUDA-style atomicMin scatter a GPU port would use):
scatter has no efficient TPU lowering, so edges are *pre-tiled by
destination* (host-side, one-time — the layout is as static as the CSR
itself) and each grid step produces one VB-wide vertex tile with a one-hot
masked min-reduce, which is pure VPU work over an [EB, VB] tile held in
VMEM. The source-distance gather is a 1-D dynamic gather from the
VMEM-resident distance vector (Mosaic ``DynamicGatherOp``; validated here
in interpret mode since the container is CPU-only).

Four entry points, in increasing integration with the solver:

- ``relax_dst_tiled``: one unmasked sweep (the original micro-benchmark
  kernel). Grid ``(n_vtiles, n_chunks)``.
- ``relax_dst_tiled_masked``: one sweep with the local solver's full
  contract — frontier masking (only edges whose source improved last sweep
  relax), per-edge Trishla pruned masks, and relaxation counting (the TEPS
  numerator). Grid ``(n_vtiles, n_chunks)`` + an SMEM count accumulator.
- ``relax_dst_tiled_fixpoint``: the fused local solve — the whole
  frontier-chased fixpoint runs inside ONE ``pallas_call`` with grid
  ``(n_sweeps, n_vtiles, n_chunks)`` instead of re-entering XLA per sweep.
- ``relax_dst_tiled_fixpoint_batch``: the fixpoint over a leading query
  axis ``K`` (multi-source SSSP). Grid ``(n_sweeps, n_vtiles, n_chunks,
  K)`` with the query axis INNERMOST: the edge-chunk block index map
  depends only on ``(i, j)``, so one fetched chunk is reused by all K
  queries before the next chunk streams in — the dst-tiled layout is
  amortized across the whole batch. Distances/frontiers are per-query
  ``[K, block_pad]`` rows; the SMEM early-out flag and the relaxation
  counter become per-query ``[K]`` vectors, so a converged query degrades
  to predicated no-op grid steps while stragglers keep relaxing.
  Distances update in place (Gauss–Seidel within a sweep: tiles later in
  the grid see earlier tiles' improvements, which only accelerates
  convergence of the monotone min-plus operator). The frontier for sweep
  ``s`` is recomputed at sweep start as ``dist < prev`` (vertices improved
  during sweep ``s-1``); an SMEM ``changed`` flag early-outs the remaining
  sweeps once a sweep makes no improvement, so a converged call costs only
  predicated no-op grid steps. Returns the residual frontier (vertices
  improved in the final sweep) so a thin outer loop can re-invoke the
  kernel until empty when ``n_sweeps`` did not suffice.

The chunk axis streams over a tile's edge list in EB-sized pieces,
revisiting the same output block (reduction pattern; initialized at chunk 0
/ sweep 0).

VMEM working set per step:
  dist (full block)            4 * block_pad
  prev + frontier (fixpoint)   8 * block_pad
  edge chunk (src, w, dstrel, pruned) ~16 * EB
  one-hot tile                 4 * EB * VB   (dominant; 512*128*4 = 256 KiB)
The batched variant multiplies the dist/prev/frontier terms by K (the
in/out distance and scratch buffers are [K, block_pad] and resident for
the whole call); the edge chunk and one-hot terms are unchanged — that is
the VMEM price of reusing one edge stream for K queries.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.tile_reduce import tile_min

INF = float("inf")


def _relax_kernel(dist_ref, src_ref, w_ref, dstrel_ref, out_ref, *, vb: int):
    i = pl.program_id(0)   # vertex tile
    j = pl.program_id(1)   # edge chunk within the tile

    # initialize the output tile from the current distances on first visit
    @pl.when(j == 0)
    def _init():
        out_ref[...] = dist_ref[pl.dslice(i * vb, vb)]

    src = src_ref[0, 0, :]                 # [EB] int32 (sentinel = block_pad-1)
    w = w_ref[0, 0, :]                     # [EB] f32 (+inf padding)
    dstrel = dstrel_ref[0, 0, :]           # [EB] int32 in [0, vb)

    d_src = jnp.take(dist_ref[...], src)   # 1-D dynamic gather from VMEM
    cand = d_src + w                       # [EB]
    mins = _tile_min(cand, dstrel, vb=vb)
    out_ref[...] = jnp.minimum(out_ref[...], mins)


def relax_dst_tiled(dist_pad, src_t, w_t, dstrel_t, *, vb: int, eb: int,
                    interpret: bool = True):
    """dist_pad: [block_pad] f32 (block_pad % vb == 0).
    src_t/w_t/dstrel_t: [n_vtiles, n_chunks, EB] dst-tiled edge layout.
    Returns new distances [block_pad]."""
    n_vtiles, n_chunks, eb_l = src_t.shape
    assert eb_l == eb and dist_pad.shape[0] == n_vtiles * vb

    grid = (n_vtiles, n_chunks)
    kernel = functools.partial(_relax_kernel, vb=vb)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(dist_pad.shape, lambda i, j: (0,)),          # full dist
            pl.BlockSpec((1, 1, eb), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, 1, eb), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, 1, eb), lambda i, j: (i, j, 0)),
        ],
        out_specs=pl.BlockSpec((vb,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((n_vtiles * vb,), dist_pad.dtype),
        interpret=interpret,
    )(dist_pad, src_t, w_t, dstrel_t)


def _edge_chunk(src_ref, w_ref, dstrel_ref, pruned_ref):
    """Load one [EB] edge chunk with the Trishla mask folded into w."""
    src = src_ref[0, 0, :]
    w = jnp.where(pruned_ref[0, 0, :] > 0, INF, w_ref[0, 0, :])
    dstrel = dstrel_ref[0, 0, :]
    return src, w, dstrel


def _tile_min(cand, dstrel, *, vb: int):
    """[EB] candidates -> [VB] per-destination minima (shared one-hot
    reduce from ``kernels/tile_reduce``)."""
    return tile_min(cand, dstrel, width=vb)


def _relax_masked_kernel(dist_ref, front_ref, src_ref, w_ref, dstrel_ref,
                         pruned_ref, out_ref, nrel_ref, acc_ref, *, vb: int,
                         n_vtiles: int, n_chunks: int):
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when((i == 0) & (j == 0))
    def _init_acc():
        acc_ref[0] = 0

    @pl.when(j == 0)
    def _init():
        out_ref[...] = dist_ref[pl.dslice(i * vb, vb)]

    src, w, dstrel = _edge_chunk(src_ref, w_ref, dstrel_ref, pruned_ref)
    f_src = jnp.take(front_ref[...], src) > 0
    d_src = jnp.take(dist_ref[...], src)
    cand = jnp.where(f_src, d_src + w, INF)
    acc_ref[0] = acc_ref[0] + jnp.sum(f_src & (w < INF)).astype(jnp.int32)
    mins = _tile_min(cand, dstrel, vb=vb)
    out_ref[...] = jnp.minimum(out_ref[...], mins)

    @pl.when((i == n_vtiles - 1) & (j == n_chunks - 1))
    def _fin():
        nrel_ref[0] = acc_ref[0]


def relax_dst_tiled_masked(dist_pad, front_pad, src_t, w_t, dstrel_t,
                           pruned_t, *, vb: int, eb: int,
                           interpret: bool = True):
    """One frontier-masked, Trishla-pruned sweep with relaxation counting.

    front_pad: [block_pad] f32 0/1; pruned_t: [n_vtiles, n_chunks, EB] int32
    0/1 in tiled edge order. Returns (new_dist [block_pad], n_relax [1])."""
    n_vtiles, n_chunks, eb_l = src_t.shape
    assert eb_l == eb and dist_pad.shape[0] == n_vtiles * vb

    bp = dist_pad.shape[0]
    grid = (n_vtiles, n_chunks)
    edge_spec = pl.BlockSpec((1, 1, eb), lambda i, j: (i, j, 0))
    kernel = functools.partial(_relax_masked_kernel, vb=vb,
                               n_vtiles=n_vtiles, n_chunks=n_chunks)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bp,), lambda i, j: (0,)),
            pl.BlockSpec((bp,), lambda i, j: (0,)),
            edge_spec, edge_spec, edge_spec, edge_spec,
        ],
        out_specs=[
            pl.BlockSpec((vb,), lambda i, j: (i,)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bp,), dist_pad.dtype),
            jax.ShapeDtypeStruct((1,), jnp.int32),
        ],
        scratch_shapes=[pltpu.SMEM((1,), jnp.int32)],
        interpret=interpret,
    )(dist_pad, front_pad, src_t, w_t, dstrel_t, pruned_t)


def _relax_fixpoint_kernel(dist_ref, front_ref, src_ref, w_ref, dstrel_ref,
                           pruned_ref, out_ref, resid_ref, nrel_ref,
                           prev_ref, fcur_ref, flags_ref, *, vb: int,
                           n_vtiles: int, n_chunks: int, n_sweeps: int):
    """Whole local fixpoint in one grid: (sweep, vertex tile, edge chunk).

    SMEM flags: [0] = sweep-active (early-out once a sweep changes
    nothing), [1] = relaxation count accumulator."""
    s = pl.program_id(0)
    i = pl.program_id(1)
    j = pl.program_id(2)
    first = (s == 0) & (i == 0) & (j == 0)
    sweep_start = (i == 0) & (j == 0)
    last = (s == n_sweeps - 1) & (i == n_vtiles - 1) & (j == n_chunks - 1)

    @pl.when(first)
    def _init():
        out_ref[...] = dist_ref[...]
        prev_ref[...] = dist_ref[...]
        fcur_ref[...] = front_ref[...]
        flags_ref[0] = jnp.any(front_ref[...] > 0).astype(jnp.int32)
        flags_ref[1] = 0

    @pl.when(sweep_start & (s > 0) & (flags_ref[0] > 0))
    def _advance_frontier():
        newf = (out_ref[...] < prev_ref[...]).astype(jnp.float32)
        fcur_ref[...] = newf
        flags_ref[0] = jnp.any(newf > 0).astype(jnp.int32)
        prev_ref[...] = out_ref[...]

    @pl.when(flags_ref[0] > 0)
    def _relax():
        src, w, dstrel = _edge_chunk(src_ref, w_ref, dstrel_ref, pruned_ref)
        f_src = jnp.take(fcur_ref[...], src) > 0
        # Gauss–Seidel: gather from the live distances, not a sweep snapshot
        d_src = jnp.take(out_ref[...], src)
        cand = jnp.where(f_src, d_src + w, INF)
        flags_ref[1] = flags_ref[1] + jnp.sum(f_src & (w < INF)).astype(jnp.int32)
        mins = _tile_min(cand, dstrel, vb=vb)
        cur = out_ref[pl.dslice(i * vb, vb)]
        out_ref[pl.dslice(i * vb, vb)] = jnp.minimum(cur, mins)

    @pl.when(last)
    def _fin():
        resid_ref[...] = (out_ref[...] < prev_ref[...]).astype(jnp.float32)
        nrel_ref[0] = flags_ref[1]


def relax_dst_tiled_fixpoint(dist_pad, front_pad, src_t, w_t, dstrel_t,
                             pruned_t, *, vb: int, eb: int, n_sweeps: int,
                             interpret: bool = True):
    """Fused multi-sweep local solve: up to ``n_sweeps`` frontier-chased
    relaxation sweeps inside one ``pallas_call``.

    Returns (new_dist [block_pad], residual_frontier [block_pad] f32 0/1,
    n_relax [1] i32). The residual frontier is empty iff the fixpoint was
    reached within ``n_sweeps`` — callers loop on it."""
    n_vtiles, n_chunks, eb_l = src_t.shape
    assert eb_l == eb and dist_pad.shape[0] == n_vtiles * vb

    bp = dist_pad.shape[0]
    grid = (n_sweeps, n_vtiles, n_chunks)
    full_spec = pl.BlockSpec((bp,), lambda s, i, j: (0,))
    edge_spec = pl.BlockSpec((1, 1, eb), lambda s, i, j: (i, j, 0))
    kernel = functools.partial(_relax_fixpoint_kernel, vb=vb,
                               n_vtiles=n_vtiles, n_chunks=n_chunks,
                               n_sweeps=n_sweeps)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[full_spec, full_spec,
                  edge_spec, edge_spec, edge_spec, edge_spec],
        out_specs=[
            full_spec,                                   # live distances
            full_spec,                                   # residual frontier
            pl.BlockSpec((1,), lambda s, i, j: (0,)),    # relaxation count
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bp,), dist_pad.dtype),
            jax.ShapeDtypeStruct((bp,), jnp.float32),
            jax.ShapeDtypeStruct((1,), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bp,), jnp.float32),              # prev-sweep snapshot
            pltpu.VMEM((bp,), jnp.float32),              # current frontier
            pltpu.SMEM((2,), jnp.int32),                 # active flag, count
        ],
        interpret=interpret,
    )(dist_pad, front_pad, src_t, w_t, dstrel_t, pruned_t)


def _relax_fixpoint_batch_kernel(dist_ref, front_ref, src_ref, w_ref,
                                 dstrel_ref, pruned_ref, out_ref, resid_ref,
                                 nrel_ref, prev_ref, fcur_ref, active_ref,
                                 count_ref, *, vb: int, n_vtiles: int,
                                 n_chunks: int, n_sweeps: int):
    """Fixpoint kernel with a query axis. Grid (sweep, vtile, chunk, query);
    the query axis is innermost so the edge chunk loaded for (vtile, chunk)
    is reused by every query before the next chunk streams in.

    Per-query SMEM state: ``active_ref[q]`` (early-out once query q's sweep
    changes nothing) and ``count_ref[q]`` (relaxation accumulator).
    ``prev_ref``/``fcur_ref`` are [K, block_pad] VMEM scratch rows."""
    s = pl.program_id(0)
    i = pl.program_id(1)
    j = pl.program_id(2)
    q = pl.program_id(3)
    first = (s == 0) & (i == 0) & (j == 0)
    sweep_start = (i == 0) & (j == 0)
    last = (s == n_sweeps - 1) & (i == n_vtiles - 1) & (j == n_chunks - 1)
    qrow = pl.dslice(q, 1)

    @pl.when(first)
    def _init():
        out_ref[qrow, :] = dist_ref[qrow, :]
        prev_ref[qrow, :] = dist_ref[qrow, :]
        fcur_ref[qrow, :] = front_ref[qrow, :]
        active_ref[q] = jnp.any(front_ref[qrow, :] > 0).astype(jnp.int32)
        count_ref[q] = 0

    @pl.when(sweep_start & (s > 0) & (active_ref[q] > 0))
    def _advance_frontier():
        newf = (out_ref[qrow, :] < prev_ref[qrow, :]).astype(jnp.float32)
        fcur_ref[qrow, :] = newf
        active_ref[q] = jnp.any(newf > 0).astype(jnp.int32)
        prev_ref[qrow, :] = out_ref[qrow, :]

    @pl.when(active_ref[q] > 0)
    def _relax():
        src, w, dstrel = _edge_chunk(src_ref, w_ref, dstrel_ref, pruned_ref)
        f_src = jnp.take(fcur_ref[qrow, :][0], src) > 0
        # Gauss–Seidel: gather from query q's live distances
        d_src = jnp.take(out_ref[qrow, :][0], src)
        cand = jnp.where(f_src, d_src + w, INF)
        count_ref[q] = count_ref[q] + jnp.sum(f_src & (w < INF)).astype(jnp.int32)
        mins = _tile_min(cand, dstrel, vb=vb)
        cur = out_ref[qrow, pl.dslice(i * vb, vb)]
        out_ref[qrow, pl.dslice(i * vb, vb)] = jnp.minimum(cur, mins)

    @pl.when(last)
    def _fin():
        resid_ref[qrow, :] = (out_ref[qrow, :] < prev_ref[qrow, :]).astype(
            jnp.float32)
        nrel_ref[q] = count_ref[q]


def relax_dst_tiled_fixpoint_batch(dist_pad, front_pad, src_t, w_t, dstrel_t,
                                   pruned_t, *, vb: int, eb: int,
                                   n_sweeps: int, interpret: bool = True):
    """Batched multi-query fixpoint: ``dist_pad``/``front_pad`` are
    [K, block_pad]; the dst-tiled edge layout (and the Trishla pruned mask)
    is SHARED by all K queries — built/gathered once, streamed once per
    (vtile, chunk) grid step and reused K times.

    Returns (new_dist [K, block_pad], residual_frontier [K, block_pad] f32
    0/1, n_relax [K] i32). A query's residual row is empty iff its fixpoint
    was reached within ``n_sweeps``."""
    n_vtiles, n_chunks, eb_l = src_t.shape
    nq, bp = dist_pad.shape
    assert eb_l == eb and bp == n_vtiles * vb

    grid = (n_sweeps, n_vtiles, n_chunks, nq)
    # Every dist-shaped buffer uses a CONSTANT full-array block: the live
    # distances are read back on every revisit (Gauss–Seidel gather + min
    # accumulate), and a revisited out block is only guaranteed to keep its
    # data — and to not be flushed to HBM once per grid step — when its
    # block index never changes between steps (same argument as the
    # single-query kernel's constant out spec). The kernel addresses query
    # rows with pl.dslice(q, 1).
    full_spec = pl.BlockSpec((nq, bp), lambda s, i, j, q: (0, 0))
    edge_spec = pl.BlockSpec((1, 1, eb), lambda s, i, j, q: (i, j, 0))
    kernel = functools.partial(_relax_fixpoint_batch_kernel, vb=vb,
                               n_vtiles=n_vtiles, n_chunks=n_chunks,
                               n_sweeps=n_sweeps)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[full_spec, full_spec,
                  edge_spec, edge_spec, edge_spec, edge_spec],
        out_specs=[
            full_spec,                                    # live distances
            full_spec,                                    # residual frontiers
            pl.BlockSpec((nq,), lambda s, i, j, q: (0,)), # per-query counts
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nq, bp), dist_pad.dtype),
            jax.ShapeDtypeStruct((nq, bp), jnp.float32),
            jax.ShapeDtypeStruct((nq,), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((nq, bp), jnp.float32),           # prev-sweep snapshots
            pltpu.VMEM((nq, bp), jnp.float32),           # current frontiers
            pltpu.SMEM((nq,), jnp.int32),                # per-query active
            pltpu.SMEM((nq,), jnp.int32),                # per-query count
        ],
        interpret=interpret,
    )(dist_pad, front_pad, src_t, w_t, dstrel_t, pruned_t)


def _edge_chunk_ragged(src_ref, w_ref, dstrel_ref, pruned_ref):
    """Load one [EB] chunk row of a ragged (flat-chunk) layout."""
    src = src_ref[0, :]
    w = jnp.where(pruned_ref[0, :] > 0, INF, w_ref[0, :])
    dstrel = dstrel_ref[0, :]
    return src, w, dstrel


def _relax_ragged_fixpoint_batch_kernel(ctile_ref, dist_ref, front_ref,
                                        src_ref, w_ref, dstrel_ref,
                                        pruned_ref, out_ref, resid_ref,
                                        nrel_ref, prev_ref, fcur_ref,
                                        active_ref, count_ref, *, vb: int,
                                        n_vtiles: int, total_chunks: int,
                                        n_sweeps: int):
    """Ragged-grid batched fixpoint. Grid (sweep, chunk, query): the vertex
    tile axis of the dense kernel is gone — each flat chunk carries its
    destination tile in the scalar-prefetched ``ctile`` map, so padding
    chunks of under-full tiles are never scheduled. Inert padding chunks
    (stacking shards to a common chunk count) carry w=+inf and the
    out-of-range tile sentinel ``n_vtiles``, clamped here to a valid tile:
    their min-accumulation is a no-op, preserving bit-identity with the
    dense schedule (same stable dst-sorted chunk sequence, minus no-ops)."""
    s = pl.program_id(0)
    c = pl.program_id(1)
    q = pl.program_id(2)
    t = jnp.minimum(ctile_ref[c], n_vtiles - 1)
    first = (s == 0) & (c == 0)
    sweep_start = (c == 0)
    last = (s == n_sweeps - 1) & (c == total_chunks - 1)
    qrow = pl.dslice(q, 1)

    @pl.when(first)
    def _init():
        out_ref[qrow, :] = dist_ref[qrow, :]
        prev_ref[qrow, :] = dist_ref[qrow, :]
        fcur_ref[qrow, :] = front_ref[qrow, :]
        active_ref[q] = jnp.any(front_ref[qrow, :] > 0).astype(jnp.int32)
        count_ref[q] = 0

    @pl.when(sweep_start & (s > 0) & (active_ref[q] > 0))
    def _advance_frontier():
        newf = (out_ref[qrow, :] < prev_ref[qrow, :]).astype(jnp.float32)
        fcur_ref[qrow, :] = newf
        active_ref[q] = jnp.any(newf > 0).astype(jnp.int32)
        prev_ref[qrow, :] = out_ref[qrow, :]

    @pl.when(active_ref[q] > 0)
    def _relax():
        src, w, dstrel = _edge_chunk_ragged(src_ref, w_ref, dstrel_ref,
                                            pruned_ref)
        f_src = jnp.take(fcur_ref[qrow, :][0], src) > 0
        d_src = jnp.take(out_ref[qrow, :][0], src)
        cand = jnp.where(f_src, d_src + w, INF)
        count_ref[q] = count_ref[q] + jnp.sum(f_src & (w < INF)).astype(jnp.int32)
        mins = _tile_min(cand, dstrel, vb=vb)
        cur = out_ref[qrow, pl.dslice(t * vb, vb)]
        out_ref[qrow, pl.dslice(t * vb, vb)] = jnp.minimum(cur, mins)

    @pl.when(last)
    def _fin():
        resid_ref[qrow, :] = (out_ref[qrow, :] < prev_ref[qrow, :]).astype(
            jnp.float32)
        nrel_ref[q] = count_ref[q]


def relax_dst_ragged_fixpoint_batch(dist_pad, front_pad, ctile, src_r, w_r,
                                    dstrel_r, pruned_r, *, vb: int, eb: int,
                                    n_sweeps: int, interpret: bool = True):
    """Ragged counterpart of ``relax_dst_tiled_fixpoint_batch``.

    ``src_r``/``w_r``/``dstrel_r``/``pruned_r`` are [total_chunks, EB] flat
    CSR-chunked rows; ``ctile`` is the [total_chunks] int32 chunk→tile map
    (sentinel ``n_vtiles`` marks inert padding chunks). The grid has
    ``total_chunks = sum_t ceil(count_t / EB)`` steps per sweep instead of
    the dense ``n_vtiles * max_t ceil(count_t / EB)`` — on skewed
    (power-law) tiles that is the whole memory/compute win."""
    total_chunks, eb_l = src_r.shape
    nq, bp = dist_pad.shape
    assert eb_l == eb and bp % vb == 0
    n_vtiles = bp // vb

    grid = (n_sweeps, total_chunks, nq)
    full_spec = pl.BlockSpec((nq, bp), lambda s, c, q, ctile: (0, 0))
    edge_spec = pl.BlockSpec((1, eb), lambda s, c, q, ctile: (c, 0))
    kernel = functools.partial(_relax_ragged_fixpoint_batch_kernel, vb=vb,
                               n_vtiles=n_vtiles, total_chunks=total_chunks,
                               n_sweeps=n_sweeps)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[full_spec, full_spec,
                  edge_spec, edge_spec, edge_spec, edge_spec],
        out_specs=[
            full_spec,                                       # live distances
            full_spec,                                       # residual frontiers
            pl.BlockSpec((nq,), lambda s, c, q, ctile: (0,)),
        ],
        scratch_shapes=[
            pltpu.VMEM((nq, bp), jnp.float32),
            pltpu.VMEM((nq, bp), jnp.float32),
            pltpu.SMEM((nq,), jnp.int32),
            pltpu.SMEM((nq,), jnp.int32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((nq, bp), dist_pad.dtype),
            jax.ShapeDtypeStruct((nq, bp), jnp.float32),
            jax.ShapeDtypeStruct((nq,), jnp.int32),
        ],
        interpret=interpret,
    )(ctile, dist_pad, front_pad, src_r, w_r, dstrel_r, pruned_r)
