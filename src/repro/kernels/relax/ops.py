"""jit'd wrappers + host-side dst-tiled layout builder for the relax kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.relax.relax import (
    relax_dst_ragged_fixpoint_batch, relax_dst_tiled,
    relax_dst_tiled_fixpoint, relax_dst_tiled_fixpoint_batch,
    relax_dst_tiled_masked,
)


def build_dst_tiled_layout(src, dst, w, n_vertices: int, *, vb: int = 128,
                           eb: int = 512, with_eid: bool = False):
    """One-time host preprocessing: edges -> [n_vtiles, n_chunks, EB] layout.

    Padding entries use src = block_pad - 1 (gather stays in range; the
    padded distance slot is +inf) and w = +inf so they never win the min.

    With ``with_eid=True`` also returns eid_t: the position of each tiled
    slot in the ORIGINAL edge list (sentinel = len(src) for padding), so
    runtime per-edge state (the Trishla pruned mask) can be gathered into
    tiled order without rebuilding the layout.
    """
    src = np.asarray(src, np.int64)
    dst = np.asarray(dst, np.int64)
    w = np.asarray(w, np.float32)
    n_edges = len(src)
    eid = np.arange(n_edges, dtype=np.int64)
    keep = np.isfinite(w)
    src, dst, w, eid = src[keep], dst[keep], w[keep], eid[keep]

    n_vtiles = max(-(-n_vertices // vb), 1)
    block_pad = n_vtiles * vb
    order = np.argsort(dst, kind="stable")
    src, dst, w, eid = src[order], dst[order], w[order], eid[order]
    tile_of = dst // vb
    counts = np.bincount(tile_of, minlength=n_vtiles)
    n_chunks = max(int(-(-counts.max() // eb)) if counts.size else 1, 1)

    src_t = np.full((n_vtiles, n_chunks * eb), block_pad - 1, np.int64)
    w_t = np.full((n_vtiles, n_chunks * eb), np.inf, np.float32)
    dstrel_t = np.zeros((n_vtiles, n_chunks * eb), np.int64)
    eid_t = np.full((n_vtiles, n_chunks * eb), n_edges, np.int64)
    starts = np.zeros(n_vtiles + 1, np.int64)
    starts[1:] = np.cumsum(counts)
    for t in range(n_vtiles):
        lo, hi = starts[t], starts[t + 1]
        k = hi - lo
        src_t[t, :k] = src[lo:hi]
        w_t[t, :k] = w[lo:hi]
        dstrel_t[t, :k] = dst[lo:hi] - t * vb
        eid_t[t, :k] = eid[lo:hi]

    shape3 = (n_vtiles, n_chunks, eb)
    out = (jnp.asarray(src_t.reshape(shape3), jnp.int32),
           jnp.asarray(w_t.reshape(shape3), jnp.float32),
           jnp.asarray(dstrel_t.reshape(shape3), jnp.int32))
    if with_eid:
        return out + (jnp.asarray(eid_t.reshape(shape3), jnp.int32), block_pad)
    return out + (block_pad,)


def build_dst_ragged_layout(src, dst, w, n_vertices: int, *, vb: int = 128,
                            eb: int = 512, with_eid: bool = False):
    """CSR-chunked (ragged) dst layout: edges -> [total_chunks, EB] rows
    plus a [total_chunks] chunk→tile map.

    Same stable dst-sort and per-tile EB split as ``build_dst_tiled_layout``
    — chunk CONTENTS are identical; only the worst-case padding chunks of
    under-full tiles are dropped, so ``total_chunks = sum_t ceil(count_t /
    EB)`` instead of ``n_vtiles * max_t ceil(count_t / EB)``. Built
    directly (never materializes the dense array), so a skewed 10M-edge
    tile histogram costs O(edges), not O(worst case × tiles).

    Returns (src_r, w_r, dstrel_r[, eid_r], ctile, block_pad). Padding
    entries inside a partly-filled chunk mirror the dense builder (src =
    block_pad - 1, w = +inf, eid sentinel = len(src)).
    """
    src = np.asarray(src, np.int64)
    dst = np.asarray(dst, np.int64)
    w = np.asarray(w, np.float32)
    n_edges = len(src)
    eid = np.arange(n_edges, dtype=np.int64)
    keep = np.isfinite(w)
    src, dst, w, eid = src[keep], dst[keep], w[keep], eid[keep]

    n_vtiles = max(-(-n_vertices // vb), 1)
    block_pad = n_vtiles * vb
    order = np.argsort(dst, kind="stable")
    src, dst, w, eid = src[order], dst[order], w[order], eid[order]
    tile_of = dst // vb
    counts = np.bincount(tile_of, minlength=n_vtiles)
    chunks_per_tile = -(-counts // eb)                 # ceil, 0 for empty tiles
    total_chunks = max(int(chunks_per_tile.sum()), 1)

    src_r = np.full((total_chunks, eb), block_pad - 1, np.int64)
    w_r = np.full((total_chunks, eb), np.inf, np.float32)
    dstrel_r = np.zeros((total_chunks, eb), np.int64)
    eid_r = np.full((total_chunks, eb), n_edges, np.int64)
    ctile = np.full(total_chunks, n_vtiles, np.int64)  # sentinel: inert chunk
    starts = np.zeros(n_vtiles + 1, np.int64)
    starts[1:] = np.cumsum(counts)
    row = 0
    for t in range(n_vtiles):
        lo, hi = starts[t], starts[t + 1]
        for off in range(lo, hi, eb):
            k = min(eb, hi - off)
            src_r[row, :k] = src[off:off + k]
            w_r[row, :k] = w[off:off + k]
            dstrel_r[row, :k] = dst[off:off + k] - t * vb
            eid_r[row, :k] = eid[off:off + k]
            ctile[row] = t
            row += 1

    out = (jnp.asarray(src_r, jnp.int32),
           jnp.asarray(w_r, jnp.float32),
           jnp.asarray(dstrel_r, jnp.int32))
    if with_eid:
        out = out + (jnp.asarray(eid_r, jnp.int32),)
    return out + (jnp.asarray(ctile, jnp.int32), block_pad)


@partial(jax.jit, static_argnames=("vb", "eb", "interpret"))
def relax_pallas(dist_pad, src_t, w_t, dstrel_t, *, vb: int = 128,
                 eb: int = 512, interpret: bool = True):
    return relax_dst_tiled(dist_pad, src_t, w_t, dstrel_t, vb=vb, eb=eb,
                           interpret=interpret)


@partial(jax.jit, static_argnames=("vb", "eb", "interpret"))
def relax_masked_pallas(dist_pad, front_pad, src_t, w_t, dstrel_t, pruned_t,
                        *, vb: int = 128, eb: int = 512,
                        interpret: bool = True):
    """One frontier-masked sweep. Returns (new_dist, n_relax scalar)."""
    new, nrel = relax_dst_tiled_masked(dist_pad, front_pad, src_t, w_t,
                                       dstrel_t, pruned_t, vb=vb, eb=eb,
                                       interpret=interpret)
    return new, nrel[0]


@partial(jax.jit, static_argnames=("vb", "eb", "n_sweeps", "interpret"))
def relax_fixpoint_pallas(dist_pad, front_pad, src_t, w_t, dstrel_t, pruned_t,
                          *, vb: int = 128, eb: int = 512, n_sweeps: int = 8,
                          interpret: bool = True):
    """Fused multi-sweep solve. Returns (new_dist, residual_frontier, n_relax)."""
    new, resid, nrel = relax_dst_tiled_fixpoint(
        dist_pad, front_pad, src_t, w_t, dstrel_t, pruned_t, vb=vb, eb=eb,
        n_sweeps=n_sweeps, interpret=interpret)
    return new, resid, nrel[0]


@partial(jax.jit, static_argnames=("vb", "eb", "n_sweeps", "interpret"))
def relax_fixpoint_batch_pallas(dist_pad, front_pad, src_t, w_t, dstrel_t,
                                pruned_t, *, vb: int = 128, eb: int = 512,
                                n_sweeps: int = 8, interpret: bool = True):
    """Batched fused solve over a leading query axis K (shared edge layout).

    dist_pad/front_pad: [K, block_pad]. Returns (new_dist [K, block_pad],
    residual_frontier [K, block_pad], n_relax [K])."""
    return relax_dst_tiled_fixpoint_batch(
        dist_pad, front_pad, src_t, w_t, dstrel_t, pruned_t, vb=vb, eb=eb,
        n_sweeps=n_sweeps, interpret=interpret)


@partial(jax.jit, static_argnames=("vb", "eb", "n_sweeps", "interpret"))
def relax_fixpoint_batch_ragged_pallas(dist_pad, front_pad, ctile, src_r, w_r,
                                       dstrel_r, pruned_r, *, vb: int = 128,
                                       eb: int = 512, n_sweeps: int = 8,
                                       interpret: bool = True):
    """Ragged-grid batched fused solve (CSR-chunked layout + chunk→tile map).

    Same contract as ``relax_fixpoint_batch_pallas`` with the flat
    [total_chunks, EB] layout from ``build_dst_ragged_layout``."""
    return relax_dst_ragged_fixpoint_batch(
        dist_pad, front_pad, ctile, src_r, w_r, dstrel_r, pruned_r, vb=vb,
        eb=eb, n_sweeps=n_sweeps, interpret=interpret)


@jax.jit
def relax_jnp(dist, src, dst, w):
    """XLA fallback (same as ref but jit'd for benchmarking)."""
    d_src = jnp.take(dist, src, mode="fill", fill_value=float("inf"))
    return dist.at[dst].min(d_src + w, mode="drop")
