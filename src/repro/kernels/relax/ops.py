"""jit'd wrapper + host-side dst-tiled layout builder for the relax kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.relax.relax import relax_dst_tiled


def build_dst_tiled_layout(src, dst, w, n_vertices: int, *, vb: int = 128,
                           eb: int = 512):
    """One-time host preprocessing: edges -> [n_vtiles, n_chunks, EB] layout.

    Padding entries use src = block_pad - 1 (gather stays in range; the
    padded distance slot is +inf) and w = +inf so they never win the min.
    """
    src = np.asarray(src, np.int64)
    dst = np.asarray(dst, np.int64)
    w = np.asarray(w, np.float32)
    keep = np.isfinite(w)
    src, dst, w = src[keep], dst[keep], w[keep]

    n_vtiles = max(-(-n_vertices // vb), 1)
    block_pad = n_vtiles * vb
    order = np.argsort(dst, kind="stable")
    src, dst, w = src[order], dst[order], w[order]
    tile_of = dst // vb
    counts = np.bincount(tile_of, minlength=n_vtiles)
    n_chunks = max(int(-(-counts.max() // eb)) if counts.size else 1, 1)

    src_t = np.full((n_vtiles, n_chunks * eb), block_pad - 1, np.int64)
    w_t = np.full((n_vtiles, n_chunks * eb), np.inf, np.float32)
    dstrel_t = np.zeros((n_vtiles, n_chunks * eb), np.int64)
    starts = np.zeros(n_vtiles + 1, np.int64)
    starts[1:] = np.cumsum(counts)
    for t in range(n_vtiles):
        lo, hi = starts[t], starts[t + 1]
        k = hi - lo
        src_t[t, :k] = src[lo:hi]
        w_t[t, :k] = w[lo:hi]
        dstrel_t[t, :k] = dst[lo:hi] - t * vb

    shape3 = (n_vtiles, n_chunks, eb)
    return (jnp.asarray(src_t.reshape(shape3), jnp.int32),
            jnp.asarray(w_t.reshape(shape3), jnp.float32),
            jnp.asarray(dstrel_t.reshape(shape3), jnp.int32),
            block_pad)


@partial(jax.jit, static_argnames=("vb", "eb", "interpret"))
def relax_pallas(dist_pad, src_t, w_t, dstrel_t, *, vb: int = 128,
                 eb: int = 512, interpret: bool = True):
    return relax_dst_tiled(dist_pad, src_t, w_t, dstrel_t, vb=vb, eb=eb,
                           interpret=interpret)


@jax.jit
def relax_jnp(dist, src, dst, w):
    """XLA fallback (same as ref but jit'd for benchmarking)."""
    d_src = jnp.take(dist, src, mode="fill", fill_value=float("inf"))
    return dist.at[dst].min(d_src + w, mode="drop")
