"""Pure-jnp oracle for min-plus edge relaxation.

new_dist[v] = min(dist[v], min_{(u,v,w) in E} dist[u] + w)
"""
from __future__ import annotations

import jax.numpy as jnp


def relax_ref(dist, src, dst, w):
    """dist: [n] f32; src/dst: [e] int32 (n = OOB sentinel); w: [e] f32."""
    d_src = jnp.take(dist, src, mode="fill", fill_value=float("inf"))
    cand = d_src + w
    return dist.at[dst].min(cand, mode="drop")
