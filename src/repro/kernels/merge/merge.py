"""Pallas TPU kernel: merge-phase scatter-min of incoming boundary messages.

The merge phase scatters each round's incoming ``[K, P, C]`` bucketed
messages into the local distance block (``dist.at[recv_idx].min``), marks
improved vertices as the next frontier, and counts receives. Like the
relax scatter before it, ``at[].min`` has no efficient TPU lowering.

TPU adaptation, third instance of the dst-tiled pattern: the receive
routing table ``recv_idx`` is STATIC (built at partition time), so the
flat message positions ``[0, P*C)`` are pre-grouped by destination vertex
tile (host-side, one-time) into ``[n_vtiles, n_chunks, EB]`` arrays and
each grid step min-reduces one VB-wide vertex tile with the one-hot
reduce. The value gather pulls from the VMEM-resident flattened incoming
row. Unlike the edge layouts there is no weight to carry the padding mask,
so an explicit ``valid`` plane rides along (positions whose ``recv_idx``
is the sentinel never enter the layout; padding is valid = 0).

Grid ``(n_vtiles, n_chunks)`` — NO query axis. Each position chunk is
fetched once and every query in the batch reduces against it in-register
via ``tile_min_batch``, so layout tile loads per merge are ``n_tiles``
rather than ``n_tiles × K``. All chunks of tile ``i`` are complete at
``j == n_chunks - 1``, so the new-frontier plane (``new < dist``) is
emitted in-kernel at tile finalization; receive counts accumulate in
per-query SMEM counters.

VMEM working set per step:
  dist / new rows            8 * K * block_pad
  frontier plane             4 * K * block_pad
  incoming rows              4 * K * P * C
  position chunk (pos, dstrel, valid)  ~12 * EB
  one-hot expansion          4 * K * EB * VB   (dominant; batched reduce)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.tile_reduce import tile_min_batch

INF = float("inf")


def _merge_scatter_kernel(dist_ref, in_ref, pos_ref, dstrel_ref, valid_ref,
                          out_ref, front_ref, recv_ref, count_ref, *, vb: int,
                          n_vtiles: int, n_chunks: int, n_queries: int):
    """Grid (vertex tile i, position chunk j) — whole query batch per step."""
    i = pl.program_id(0)
    j = pl.program_id(1)
    first = (i == 0) & (j == 0)
    last = (i == n_vtiles - 1) & (j == n_chunks - 1)
    tile = pl.dslice(i * vb, vb)

    @pl.when(first)
    def _init_counts():
        for k in range(n_queries):
            count_ref[k] = 0

    @pl.when(j == 0)
    def _init_tile():
        out_ref[:, tile] = dist_ref[:, tile]

    pos = pos_ref[0, 0, :]                    # [EB] int32 (padding = 0)
    dstrel = dstrel_ref[0, 0, :]              # [EB] int32 in [0, vb)
    valid = valid_ref[0, 0, :] > 0            # [EB]
    v = jnp.take(in_ref[...], pos, axis=1)    # [K, EB]
    cand = jnp.where(valid[None, :], v, INF)
    sums = jnp.sum(valid[None, :] & (v < INF), axis=1).astype(jnp.int32)
    for k in range(n_queries):
        count_ref[k] = count_ref[k] + sums[k]
    mins = tile_min_batch(cand, dstrel, width=vb)     # [K, vb]
    out_ref[:, tile] = jnp.minimum(out_ref[:, tile], mins)

    # tile i complete: improved vertices form the next frontier
    @pl.when(j == n_chunks - 1)
    def _finalize_tile():
        front_ref[:, tile] = (
            out_ref[:, tile] < dist_ref[:, tile]
        ).astype(jnp.float32)

    @pl.when(last)
    def _fin():
        for k in range(n_queries):
            recv_ref[k] = count_ref[k]


def merge_scatter_tiled(dist_pad, incoming_flat, pos_t, dstrel_t, valid_t, *,
                        vb: int, eb: int, interpret: bool = True):
    """dist_pad: [K, block_pad] f32 (block_pad = n_vtiles * vb);
    incoming_flat: [K, M] f32 flattened messages; pos_t/dstrel_t/valid_t:
    [n_vtiles, n_chunks, EB] msg-tiled routing layout (query-invariant).
    Returns (new_dist [K, block_pad], new_frontier [K, block_pad] f32 0/1,
    recvs [K] i32 — finite incoming messages seen)."""
    n_vtiles, n_chunks, eb_l = pos_t.shape
    nq, bp = dist_pad.shape
    assert eb_l == eb and bp == n_vtiles * vb

    grid = (n_vtiles, n_chunks)
    dist_spec = pl.BlockSpec((nq, bp), lambda i, j: (0, 0))
    in_spec = pl.BlockSpec(incoming_flat.shape, lambda i, j: (0, 0))
    pos_spec = pl.BlockSpec((1, 1, eb), lambda i, j: (i, j, 0))
    kernel = functools.partial(_merge_scatter_kernel, vb=vb,
                               n_vtiles=n_vtiles, n_chunks=n_chunks,
                               n_queries=nq)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[dist_spec, in_spec, pos_spec, pos_spec, pos_spec],
        out_specs=[
            dist_spec,                                     # merged distances
            dist_spec,                                     # new frontier
            pl.BlockSpec((nq,), lambda i, j: (0,)),        # per-query recvs
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nq, bp), dist_pad.dtype),
            jax.ShapeDtypeStruct((nq, bp), jnp.float32),
            jax.ShapeDtypeStruct((nq,), jnp.int32),
        ],
        scratch_shapes=[pltpu.SMEM((nq,), jnp.int32)],
        interpret=interpret,
    )(dist_pad, incoming_flat, pos_t, dstrel_t, valid_t)


def _merge_scatter_ragged_kernel(ctile_ref, dist_ref, in_ref, pos_ref,
                                 dstrel_ref, valid_ref, out_ref, front_ref,
                                 recv_ref, count_ref, *, vb: int,
                                 n_vtiles: int, total_chunks: int,
                                 n_queries: int):
    """Ragged grid ``(total_chunks,)`` with the scalar-prefetched chunk→tile
    map. Tile init/finalize move to GLOBAL (whole [K, block_pad] at the
    first/last chunk): the accumulate never reads the frontier plane, so the
    result is bit-identical, and zero-chunk tiles — skipped by the ragged
    grid entirely — still get ``out = dist`` / frontier 0."""
    c = pl.program_id(0)
    t = jnp.minimum(ctile_ref[c], n_vtiles - 1)
    tile = pl.dslice(t * vb, vb)

    @pl.when(c == 0)
    def _init():
        out_ref[...] = dist_ref[...]
        for k in range(n_queries):
            count_ref[k] = 0

    pos = pos_ref[0, :]                       # [EB] int32 (padding = 0)
    dstrel = dstrel_ref[0, :]                 # [EB] int32 in [0, vb)
    valid = valid_ref[0, :] > 0               # [EB]
    v = jnp.take(in_ref[...], pos, axis=1)    # [K, EB]
    cand = jnp.where(valid[None, :], v, INF)
    sums = jnp.sum(valid[None, :] & (v < INF), axis=1).astype(jnp.int32)
    for k in range(n_queries):
        count_ref[k] = count_ref[k] + sums[k]
    mins = tile_min_batch(cand, dstrel, width=vb)     # [K, vb]
    out_ref[:, tile] = jnp.minimum(out_ref[:, tile], mins)

    @pl.when(c == total_chunks - 1)
    def _fin():
        front_ref[...] = (out_ref[...] < dist_ref[...]).astype(jnp.float32)
        for k in range(n_queries):
            recv_ref[k] = count_ref[k]


def merge_scatter_ragged(dist_pad, incoming_flat, ctile, pos_r, dstrel_r,
                         valid_r, *, vb: int, eb: int,
                         interpret: bool = True):
    """Ragged counterpart of ``merge_scatter_tiled``: pos_r/dstrel_r/valid_r
    are flat [total_chunks, EB] rows, ``ctile`` the [total_chunks] chunk→
    tile map (sentinel ``n_vtiles`` for inert padding chunks). Same
    returns."""
    total_chunks, eb_l = pos_r.shape
    nq, bp = dist_pad.shape
    assert eb_l == eb and bp % vb == 0
    n_vtiles = bp // vb

    grid = (total_chunks,)
    dist_spec = pl.BlockSpec((nq, bp), lambda c, ctile: (0, 0))
    in_spec = pl.BlockSpec(incoming_flat.shape, lambda c, ctile: (0, 0))
    pos_spec = pl.BlockSpec((1, eb), lambda c, ctile: (c, 0))
    kernel = functools.partial(_merge_scatter_ragged_kernel, vb=vb,
                               n_vtiles=n_vtiles, total_chunks=total_chunks,
                               n_queries=nq)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[dist_spec, in_spec, pos_spec, pos_spec, pos_spec],
        out_specs=[
            dist_spec,                                     # merged distances
            dist_spec,                                     # new frontier
            pl.BlockSpec((nq,), lambda c, ctile: (0,)),
        ],
        scratch_shapes=[pltpu.SMEM((nq,), jnp.int32)],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((nq, bp), dist_pad.dtype),
            jax.ShapeDtypeStruct((nq, bp), jnp.float32),
            jax.ShapeDtypeStruct((nq,), jnp.int32),
        ],
        interpret=interpret,
    )(ctile, dist_pad, incoming_flat, pos_r, dstrel_r, valid_r)
