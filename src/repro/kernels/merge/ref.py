"""Pure-jnp oracle for the merge-phase scatter-min.

Per query: new[v] = min(dist[v], min over flat positions m with
idx[m] == v of incoming[m]); improved vertices are the next frontier.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def merge_scatter_ref(dist, incoming_flat, flat_idx):
    """dist: [K, block]; incoming_flat: [K, M] f32; flat_idx: [M] int32
    (sentinel >= block = dropped). Returns (new_dist [K, block],
    new_active [K, block] bool, recvs [K] i32 — finite incoming)."""
    new = jax.vmap(
        lambda d, v: d.at[flat_idx].min(v, mode="drop"))(dist, incoming_flat)
    recvs = jnp.sum(jnp.isfinite(incoming_flat), axis=-1).astype(jnp.int32)
    return new, new < dist, recvs
