from repro.kernels.merge.ops import (
    build_msg_ragged_layout, build_msg_tiled_layout, merge_scatter_pallas,
)
from repro.kernels.merge.ref import merge_scatter_ref
