"""jit'd wrappers + host-side msg-tiled layout builder for the merge kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.merge.merge import merge_scatter_tiled

INF = float("inf")


def build_msg_tiled_layout(recv_idx, block: int, *, vb: int = 128,
                           eb: int = 512):
    """One-time host preprocessing: the static receive routing table
    ``recv_idx`` [P, C] (local vertex addressed by (sender, bucket pos);
    sentinel >= block = no message) -> flat message positions grouped by
    destination vertex tile.

    Returns (pos_t, dstrel_t, valid_t, block_pad), each layout array
    [n_vtiles, n_chunks, EB]: ``pos_t`` indexes the FLATTENED [P*C]
    incoming buffer, ``dstrel_t`` is the destination slot within its tile,
    ``valid_t`` masks padding (no weight plane exists to carry +inf here,
    unlike the edge layouts)."""
    ridx = np.asarray(recv_idx, np.int64).reshape(-1)
    pos = np.arange(ridx.shape[0], dtype=np.int64)
    keep = ridx < block
    ridx, pos = ridx[keep], pos[keep]

    n_vtiles = max(-(-block // vb), 1)
    block_pad = n_vtiles * vb
    order = np.argsort(ridx, kind="stable")
    ridx, pos = ridx[order], pos[order]
    tile_of = ridx // vb
    counts = np.bincount(tile_of, minlength=n_vtiles)
    n_chunks = max(int(-(-counts.max() // eb)) if counts.size else 1, 1)

    pos_t = np.zeros((n_vtiles, n_chunks * eb), np.int64)
    dstrel_t = np.zeros((n_vtiles, n_chunks * eb), np.int64)
    valid_t = np.zeros((n_vtiles, n_chunks * eb), np.int64)
    starts = np.zeros(n_vtiles + 1, np.int64)
    starts[1:] = np.cumsum(counts)
    for t in range(n_vtiles):
        lo, hi = starts[t], starts[t + 1]
        k = hi - lo
        pos_t[t, :k] = pos[lo:hi]
        dstrel_t[t, :k] = ridx[lo:hi] - t * vb
        valid_t[t, :k] = 1

    shape3 = (n_vtiles, n_chunks, eb)
    return (jnp.asarray(pos_t.reshape(shape3), jnp.int32),
            jnp.asarray(dstrel_t.reshape(shape3), jnp.int32),
            jnp.asarray(valid_t.reshape(shape3), jnp.int32),
            block_pad)


@partial(jax.jit, static_argnames=("vb", "eb", "interpret"))
def merge_scatter_pallas(dist, incoming_flat, pos_t, dstrel_t, valid_t, *,
                         vb: int = 128, eb: int = 512,
                         interpret: bool = True):
    """Solver-facing wrapper: pads to kernel tile shapes, slices back.

    dist: [K, block]; incoming_flat: [K, M] flattened bucketed messages.
    Returns (new_dist [K, block], new_active [K, block] bool,
    recvs [K] i32)."""
    n_vtiles = pos_t.shape[0]
    nq, block = dist.shape
    bp = n_vtiles * vb
    dist_pad = jnp.full((nq, bp), INF).at[:, :block].set(dist)
    new, front, recvs = merge_scatter_tiled(
        dist_pad, incoming_flat, pos_t, dstrel_t, valid_t, vb=vb, eb=eb,
        interpret=interpret)
    return new[:, :block], front[:, :block] > 0, recvs
