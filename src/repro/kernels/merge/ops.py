"""jit'd wrappers + host-side msg-tiled layout builder for the merge kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.merge.merge import merge_scatter_ragged, merge_scatter_tiled

INF = float("inf")


def build_msg_ragged_layout(recv_idx, block: int, *, vb: int = 128,
                            eb: int = 512):
    """Ragged (CSR-chunked) msg routing layout: the static receive table ->
    flat [total_chunks, EB] position rows + [total_chunks] chunk→tile map
    (sentinel ``n_vtiles`` for inert padding chunks; their valid plane is
    0). Same stable sort and per-tile EB split as the dense builder.

    Returns (pos_r, dstrel_r, valid_r, ctile, block_pad)."""
    ridx = np.asarray(recv_idx, np.int64).reshape(-1)
    pos = np.arange(ridx.shape[0], dtype=np.int64)
    keep = ridx < block
    ridx, pos = ridx[keep], pos[keep]

    n_vtiles = max(-(-block // vb), 1)
    block_pad = n_vtiles * vb
    order = np.argsort(ridx, kind="stable")
    ridx, pos = ridx[order], pos[order]
    tile_of = ridx // vb
    counts = np.bincount(tile_of, minlength=n_vtiles)
    chunks_per_tile = -(-counts // eb)
    total_chunks = max(int(chunks_per_tile.sum()), 1)

    pos_r = np.zeros((total_chunks, eb), np.int64)
    dstrel_r = np.zeros((total_chunks, eb), np.int64)
    valid_r = np.zeros((total_chunks, eb), np.int64)
    ctile = np.full(total_chunks, n_vtiles, np.int64)
    starts = np.zeros(n_vtiles + 1, np.int64)
    starts[1:] = np.cumsum(counts)
    row = 0
    for t in range(n_vtiles):
        lo, hi = starts[t], starts[t + 1]
        for off in range(lo, hi, eb):
            k = min(eb, hi - off)
            pos_r[row, :k] = pos[off:off + k]
            dstrel_r[row, :k] = ridx[off:off + k] - t * vb
            valid_r[row, :k] = 1
            ctile[row] = t
            row += 1

    return (jnp.asarray(pos_r, jnp.int32),
            jnp.asarray(dstrel_r, jnp.int32),
            jnp.asarray(valid_r, jnp.int32),
            jnp.asarray(ctile, jnp.int32),
            block_pad)


def build_msg_tiled_layout(recv_idx, block: int, *, vb: int = 128,
                           eb: int = 512):
    """One-time host preprocessing: the static receive routing table
    ``recv_idx`` [P, C] (local vertex addressed by (sender, bucket pos);
    sentinel >= block = no message) -> flat message positions grouped by
    destination vertex tile.

    Returns (pos_t, dstrel_t, valid_t, block_pad), each layout array
    [n_vtiles, n_chunks, EB]: ``pos_t`` indexes the FLATTENED [P*C]
    incoming buffer, ``dstrel_t`` is the destination slot within its tile,
    ``valid_t`` masks padding (no weight plane exists to carry +inf here,
    unlike the edge layouts)."""
    ridx = np.asarray(recv_idx, np.int64).reshape(-1)
    pos = np.arange(ridx.shape[0], dtype=np.int64)
    keep = ridx < block
    ridx, pos = ridx[keep], pos[keep]

    n_vtiles = max(-(-block // vb), 1)
    block_pad = n_vtiles * vb
    order = np.argsort(ridx, kind="stable")
    ridx, pos = ridx[order], pos[order]
    tile_of = ridx // vb
    counts = np.bincount(tile_of, minlength=n_vtiles)
    n_chunks = max(int(-(-counts.max() // eb)) if counts.size else 1, 1)

    pos_t = np.zeros((n_vtiles, n_chunks * eb), np.int64)
    dstrel_t = np.zeros((n_vtiles, n_chunks * eb), np.int64)
    valid_t = np.zeros((n_vtiles, n_chunks * eb), np.int64)
    starts = np.zeros(n_vtiles + 1, np.int64)
    starts[1:] = np.cumsum(counts)
    for t in range(n_vtiles):
        lo, hi = starts[t], starts[t + 1]
        k = hi - lo
        pos_t[t, :k] = pos[lo:hi]
        dstrel_t[t, :k] = ridx[lo:hi] - t * vb
        valid_t[t, :k] = 1

    shape3 = (n_vtiles, n_chunks, eb)
    return (jnp.asarray(pos_t.reshape(shape3), jnp.int32),
            jnp.asarray(dstrel_t.reshape(shape3), jnp.int32),
            jnp.asarray(valid_t.reshape(shape3), jnp.int32),
            block_pad)


@partial(jax.jit, static_argnames=("vb", "eb", "interpret"))
def merge_scatter_pallas(dist, incoming_flat, pos_t, dstrel_t, valid_t,
                         ctile=None, *, vb: int = 128, eb: int = 512,
                         interpret: bool = True):
    """Solver-facing wrapper: pads to kernel tile shapes, slices back.

    dist: [K, block]; incoming_flat: [K, M] flattened bucketed messages.
    With ``ctile`` given, the layout arrays are the flat ragged rows from
    ``build_msg_ragged_layout``. Returns (new_dist [K, block],
    new_active [K, block] bool, recvs [K] i32)."""
    nq, block = dist.shape
    n_vtiles = pos_t.shape[0] if ctile is None else max(-(-block // vb), 1)
    bp = n_vtiles * vb
    dist_pad = jnp.full((nq, bp), INF).at[:, :block].set(dist)
    if ctile is None:
        new, front, recvs = merge_scatter_tiled(
            dist_pad, incoming_flat, pos_t, dstrel_t, valid_t, vb=vb, eb=eb,
            interpret=interpret)
    else:
        new, front, recvs = merge_scatter_ragged(
            dist_pad, incoming_flat, ctile, pos_t, dstrel_t, valid_t, vb=vb,
            eb=eb, interpret=interpret)
    return new[:, :block], front[:, :block] > 0, recvs
