"""Shared VPU primitive for the dst-tiled kernel family.

All three SP-Async kernels (relax, send, merge) end in the same move: a
chunk of [EB] candidate values, each tagged with a tile-relative target in
``[0, width)``, reduced to per-target minima with a one-hot masked
min-reduce — the TPU replacement for a scatter-min. Kept in one place so
the VMEM-dominant term of every kernel (the [EB, width] one-hot tile) is
tuned once, not three times.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

INF = float("inf")


def tile_min(cand, rel, *, width: int):
    """[EB] candidates -> [width] per-target minima (one-hot reduce)."""
    eb = cand.shape[0]
    lane = jax.lax.broadcasted_iota(jnp.int32, (eb, width), 1)
    onehot = rel[:, None] == lane
    return jnp.min(jnp.where(onehot, cand[:, None], INF), axis=0)


def tile_min_batch(cand, rel, *, width: int):
    """[K, EB] candidates -> [K, width] per-target minima.

    The one-hot mask is built once from the shared [EB] target vector and
    broadcast across the query axis, so a whole query batch reduces per
    chunk load instead of re-streaming the chunk once per query."""
    k, eb = cand.shape
    lane = jax.lax.broadcasted_iota(jnp.int32, (eb, width), 1)
    onehot = rel[:, None] == lane                          # [EB, width]
    masked = jnp.where(onehot[None], cand[:, :, None], INF)
    return jnp.min(masked, axis=1)
