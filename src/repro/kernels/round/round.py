"""Pallas TPU megakernel: one SP-Async round in a single ``pallas_call``.

At today's graph scales every phase of the round (merge the previous
exchange's messages, chase the local frontier to a fixpoint, pack the
boundary sends) costs microseconds of compute — the round time IS the
per-phase dispatch overhead. All three phases already share the dst-tiled
tiling and the one-hot masked min-reduce, and all three read or write the
same [K, block_pad] distance rows, so they compose into ONE kernel whose
grid walks three stages over a shared VMEM-resident distance buffer:

  stage s = 0            merge: scatter-min the delivered messages into
                         the distance rows and derive the round's frontier
                         ``((merged < dist) & live) | injected``
  stage s in [1, S]      S Gauss–Seidel relaxation sweeps with the SMEM
                         early-out flag from ``relax_dst_tiled_fixpoint``
                         (a sweep with an empty global frontier is a
                         predicated no-op grid step)
  stage s = S + 1        send-pack: slot-tile segment-min of
                         ``dist[src] + w`` masked against ``last_sent``

Grid ``(S + 2, T, C)`` with ``T = max(tiles per stage)`` and ``C =
max(chunks per stage)`` — NO query axis; the [K] batch lives in-register
per tile via ``tile_min_batch`` exactly as in the batched per-phase
kernels, so layout tile loads per round stay ``n_tiles``, not
``n_tiles x K``. Each stage's layout refs use stage-aware index maps that
pin to block (0, 0, 0) while the stage is inactive (no refetch churn) and
clamp to valid tiles while active; validity predicates
``(i < n_xtiles) & (j < x_chunks)`` keep the clamped excess steps inert.

Like the per-phase kernels the distance buffer uses a CONSTANT full-array
BlockSpec: merged-then-relaxed-then-read-by-send values must survive
every revisit, which is only guaranteed when the block index never
changes between grid steps.

The kernel emits the residual frontier of the final sweep; when it is
non-empty (``n_sweeps`` did not reach the fixpoint) the in-kernel send
outputs were computed from unconverged distances and the caller runs the
``ops.fused_round_rescue`` continuation instead.

VMEM working set per step (bucket exchange):
  dist / prev / frontier rows   12 * K * block_pad
  incoming message rows          4 * K * P * C
  send val / last / new_last    12 * K * S_pad
  active stage's chunk          ~16 * EB
  one-hot expansion              4 * K * EB * width   (dominant)

The kernel is exchange-agnostic: the ``incoming`` operand is whatever
delivery the round hands it. Under the synchronous exchanges that is the
previous round's collective output held in ``carry.incoming``; under the
DEFERRED exchanges (``exchange="async*"``) it is a delivery that left its
sender one or more rounds earlier — the solver issues the collective for
the in-flight buffer at the top of the round, so nothing in this kernel's
dataflow depends on it and XLA is free to run the collective concurrently
with the whole grid. The scatter-min merge of stage 0 is monotone and
idempotent, which is exactly why merge lag is a round-count effect, never
a correctness one.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.tile_reduce import tile_min_batch

INF = float("inf")


def _fused_round_kernel(*refs, dense: bool, vb: int, sb: int, n_vtiles: int,
                        n_stiles: int, n_mtiles: int, rx_chunks: int,
                        tx_chunks: int, mx_chunks: int, n_sweeps: int,
                        n_queries: int, grid_t: int, grid_c: int):
    """Grid (stage s, tile i, chunk j) — whole query batch per step."""
    if dense:
        (dist_ref, front_ref, live_ref, inc_ref, last_ref, svalid_ref,
         rxsrc_ref, rxw_ref, rxdst_ref, rxprn_ref,
         txsrc_ref, txw_ref, txseg_ref, txprn_ref,
         out_ref, resid_ref, val_ref, newlast_ref, nrel_ref, sends_ref,
         prev_ref, fcur_ref, flag_ref, rcount_ref, scount_ref) = refs
        mxpos_ref = mxdst_ref = mxval_ref = None
    else:
        (dist_ref, front_ref, live_ref, inc_ref, last_ref, svalid_ref,
         mxpos_ref, mxdst_ref, mxval_ref,
         rxsrc_ref, rxw_ref, rxdst_ref, rxprn_ref,
         txsrc_ref, txw_ref, txseg_ref, txprn_ref,
         out_ref, resid_ref, val_ref, newlast_ref, nrel_ref, sends_ref,
         prev_ref, fcur_ref, flag_ref, rcount_ref, scount_ref) = refs

    s = pl.program_id(0)
    i = pl.program_id(1)
    j = pl.program_id(2)
    S = n_sweeps
    first = (s == 0) & (i == 0) & (j == 0)
    last = (s == S + 1) & (i == grid_t - 1) & (j == grid_c - 1)
    vtile = pl.dslice(i * vb, vb)
    stile = pl.dslice(i * sb, sb)
    live_col = live_ref[...][:, None] > 0             # [K, 1]

    @pl.when(first)
    def _init_counts():
        for k in range(n_queries):
            rcount_ref[k] = 0
            scount_ref[k] = 0

    # ---- stage 0: merge delivered messages, derive the frontier ----
    if dense:
        @pl.when(first)
        def _merge_dense():
            merged = jnp.minimum(dist_ref[...], inc_ref[...])
            out_ref[...] = merged
            newf = (merged < dist_ref[...]) & live_col
            fcur_ref[...] = jnp.maximum(newf.astype(jnp.float32),
                                        front_ref[...])
    else:
        m_ok = (s == 0) & (i < n_mtiles) & (j < mx_chunks)

        @pl.when(m_ok & (j == 0))
        def _init_mtile():
            out_ref[:, vtile] = dist_ref[:, vtile]

        @pl.when(m_ok)
        def _merge_chunk():
            pos = mxpos_ref[0, 0, :]              # [EB] int32 (padding = 0)
            dstrel = mxdst_ref[0, 0, :]           # [EB] int32 in [0, vb)
            valid = mxval_ref[0, 0, :] > 0
            v = jnp.take(inc_ref[...], pos, axis=1)       # [K, EB]
            cand = jnp.where(valid[None, :], v, INF)
            mins = tile_min_batch(cand, dstrel, width=vb)
            out_ref[:, vtile] = jnp.minimum(out_ref[:, vtile], mins)

        @pl.when(m_ok & (j == mx_chunks - 1))
        def _finalize_mtile():
            newf = (out_ref[:, vtile] < dist_ref[:, vtile]) & live_col
            fcur_ref[:, vtile] = jnp.maximum(newf.astype(jnp.float32),
                                             front_ref[:, vtile])

    # stage-end bookkeeping (ordered after the tile finalizers above)
    @pl.when((s == 0) & (i == grid_t - 1) & (j == grid_c - 1))
    def _merge_done():
        prev_ref[...] = out_ref[...]
        flag_ref[0] = jnp.any(fcur_ref[...] > 0).astype(jnp.int32)

    # ---- stages 1..S: frontier-chased relaxation sweeps ----
    r_stage = (s >= 1) & (s <= S)

    @pl.when(r_stage & (s > 1) & (i == 0) & (j == 0) & (flag_ref[0] > 0))
    def _advance_sweep():
        newf = (out_ref[...] < prev_ref[...]).astype(jnp.float32)
        fcur_ref[...] = newf
        flag_ref[0] = jnp.any(newf > 0).astype(jnp.int32)
        prev_ref[...] = out_ref[...]

    @pl.when(r_stage & (i < n_vtiles) & (j < rx_chunks) & (flag_ref[0] > 0))
    def _relax_chunk():
        src = rxsrc_ref[0, 0, :]                  # [EB] (padding = bp - 1)
        w = jnp.where(rxprn_ref[0, 0, :] > 0, INF, rxw_ref[0, 0, :])
        dstrel = rxdst_ref[0, 0, :]
        f_src = jnp.take(fcur_ref[...], src, axis=1) > 0  # [K, EB]
        d_src = jnp.take(out_ref[...], src, axis=1)       # Gauss–Seidel
        cand = jnp.where(f_src, d_src + w[None, :], INF)
        sums = jnp.sum(f_src & (w < INF)[None, :], axis=1).astype(jnp.int32)
        for k in range(n_queries):
            rcount_ref[k] = rcount_ref[k] + sums[k]
        mins = tile_min_batch(cand, dstrel, width=vb)
        out_ref[:, vtile] = jnp.minimum(out_ref[:, vtile], mins)

    # ---- stage S + 1: send-pack against last_sent ----
    s_ok = (s == S + 1) & (i < n_stiles) & (j < tx_chunks)

    @pl.when(s_ok & (j == 0))
    def _init_stile():
        val_ref[:, stile] = jnp.full((n_queries, sb), INF, jnp.float32)

    @pl.when(s_ok)
    def _send_chunk():
        src = txsrc_ref[0, 0, :]                  # [EB] (padding = 0)
        w = jnp.where(txprn_ref[0, 0, :] > 0, INF, txw_ref[0, 0, :])
        segrel = txseg_ref[0, 0, :]
        d_src = jnp.take(out_ref[...], src, axis=1)
        cand = d_src + w[None, :]
        mins = tile_min_batch(cand, segrel, width=sb)
        val_ref[:, stile] = jnp.minimum(val_ref[:, stile], mins)

    @pl.when(s_ok & (j == tx_chunks - 1))
    def _finalize_stile():
        val = val_ref[:, stile]
        prevl = last_ref[:, stile]
        valid = svalid_ref[stile][None, :] > 0
        improved = valid & (val < prevl)
        val_ref[:, stile] = jnp.where(improved, val, INF)
        newlast_ref[:, stile] = jnp.where(improved, val, prevl)
        sums = jnp.sum(improved, axis=1).astype(jnp.int32)
        for k in range(n_queries):
            scount_ref[k] = scount_ref[k] + sums[k]

    @pl.when(last)
    def _fin():
        resid_ref[...] = (out_ref[...] < prev_ref[...]).astype(jnp.float32)
        for k in range(n_queries):
            nrel_ref[k] = rcount_ref[k]
            sends_ref[k] = scount_ref[k]


def _stage_map(lo: int, hi: int, nt: int, nc: int):
    """Index map for a stage's layout refs: clamp to valid tiles while the
    stage is active, pin to block (0, 0, 0) otherwise (no refetch churn
    while other stages run)."""
    def m(s, i, j):
        ok = (s >= lo) & (s <= hi)
        ii = jnp.where(ok, jnp.minimum(i, nt - 1), 0)
        jj = jnp.where(ok, jnp.minimum(j, nc - 1), 0)
        return ii, jj, 0
    return m


def fused_round_tiled(dist_pad, front_pad, live, incoming, last_pad,
                      valid_pad, mx_layout, rx_layout, tx_layout, *, vb: int,
                      sb: int, n_sweeps: int, dense: bool,
                      interpret: bool = True):
    """One fused round. dist_pad/front_pad: [K, block_pad]; live: [K] f32
    0/1; incoming: [K, M] flat messages (bucket) or [K, block_pad] remote
    minima (dense); last_pad/valid_pad: [K, S_pad] / [S_pad].
    mx_layout = (pos_t, dstrel_t, valid_t) or None when dense;
    rx_layout = (src_t, w_t, dstrel_t, pruned_t);
    tx_layout = (src_t, w_t, segrel_t, pruned_t).

    Returns (new_dist [K, block_pad], resid [K, block_pad] f32 0/1,
    send_val [K, S_pad] — INF where not improved, new_last [K, S_pad],
    nrel [K] i32, sends [K] i32)."""
    rx_src, rx_w, rx_dst, rx_prn = rx_layout
    tx_src, tx_w, tx_seg, tx_prn = tx_layout
    n_vtiles, rx_chunks, rx_eb = rx_src.shape
    n_stiles, tx_chunks, tx_eb = tx_src.shape
    nq, bp = dist_pad.shape
    sp = n_stiles * sb
    assert bp == n_vtiles * vb and last_pad.shape == (nq, sp)
    S = n_sweeps

    if dense:
        assert incoming.shape == (nq, bp)
        n_mtiles, mx_chunks = 1, 1
    else:
        mx_pos, mx_dst, mx_val = mx_layout
        n_mtiles, mx_chunks, mx_eb = mx_pos.shape
        assert n_mtiles * vb == bp

    grid_t = max(n_vtiles, n_stiles, n_mtiles if not dense else 1)
    grid_c = max(rx_chunks, tx_chunks, mx_chunks if not dense else 1)
    grid = (S + 2, grid_t, grid_c)

    dist_spec = pl.BlockSpec((nq, bp), lambda s, i, j: (0, 0))
    slot_spec = pl.BlockSpec((nq, sp), lambda s, i, j: (0, 0))
    q_spec = pl.BlockSpec((nq,), lambda s, i, j: (0,))
    rx_spec = pl.BlockSpec((1, 1, rx_eb), _stage_map(1, S, n_vtiles,
                                                     rx_chunks))
    tx_spec = pl.BlockSpec((1, 1, tx_eb), _stage_map(S + 1, S + 1, n_stiles,
                                                     tx_chunks))

    in_specs = [dist_spec, dist_spec, q_spec]
    operands = [dist_pad, front_pad, live]
    if dense:
        in_specs += [dist_spec]
        operands += [incoming]
    else:
        inc_spec = pl.BlockSpec(incoming.shape, lambda s, i, j: (0, 0))
        mx_spec = pl.BlockSpec((1, 1, mx_eb), _stage_map(0, 0, n_mtiles,
                                                         mx_chunks))
        in_specs += [inc_spec]
        operands += [incoming]
    in_specs += [slot_spec, pl.BlockSpec((sp,), lambda s, i, j: (0,))]
    operands += [last_pad, valid_pad]
    if not dense:
        in_specs += [mx_spec, mx_spec, mx_spec]
        operands += [mx_pos, mx_dst, mx_val]
    in_specs += [rx_spec] * 4 + [tx_spec] * 4
    operands += [rx_src, rx_w, rx_dst, rx_prn, tx_src, tx_w, tx_seg, tx_prn]

    kernel = functools.partial(
        _fused_round_kernel, dense=dense, vb=vb, sb=sb, n_vtiles=n_vtiles,
        n_stiles=n_stiles, n_mtiles=n_mtiles, rx_chunks=rx_chunks,
        tx_chunks=tx_chunks, mx_chunks=mx_chunks, n_sweeps=S, n_queries=nq,
        grid_t=grid_t, grid_c=grid_c)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=[
            dist_spec,            # merged + relaxed distances
            dist_spec,            # residual frontier of the final sweep
            slot_spec,            # masked send values
            slot_spec,            # updated last_sent
            q_spec,               # per-query relaxations
            q_spec,               # per-query sends
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nq, bp), dist_pad.dtype),
            jax.ShapeDtypeStruct((nq, bp), jnp.float32),
            jax.ShapeDtypeStruct((nq, sp), jnp.float32),
            jax.ShapeDtypeStruct((nq, sp), jnp.float32),
            jax.ShapeDtypeStruct((nq,), jnp.int32),
            jax.ShapeDtypeStruct((nq,), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((nq, bp), jnp.float32),    # prev (sweep snapshot)
            pltpu.VMEM((nq, bp), jnp.float32),    # current frontier
            pltpu.SMEM((1,), jnp.int32),          # global early-out flag
            pltpu.SMEM((nq,), jnp.int32),         # relaxation counters
            pltpu.SMEM((nq,), jnp.int32),         # send counters
        ],
        interpret=interpret,
    )(*operands)


def _fused_round_ragged_kernel(*refs, dense: bool, vb: int, sb: int,
                               n_vtiles: int, n_stiles: int,
                               rx_chunks: int, tx_chunks: int, mx_chunks: int,
                               n_sweeps: int, n_queries: int, grid_c: int):
    """Ragged fused round: grid (stage s, flat chunk c) — the tile axis is
    folded into the scalar-prefetched per-stage chunk→tile maps, so the
    grid walks ``sum_t chunks_t`` steps per stage instead of ``max_t
    chunks_t × n_tiles``. Per-tile init/finalize become GLOBAL (first/last
    chunk of the stage): no accumulate step reads a finalizer's output, so
    the values are bit-identical to the dense schedule, and zero-chunk
    tiles — which the ragged chunk lists skip entirely — still get their
    identity init/finalize."""
    if dense:
        (rxct_ref, txct_ref,
         dist_ref, front_ref, live_ref, inc_ref, last_ref, svalid_ref,
         rxsrc_ref, rxw_ref, rxdst_ref, rxprn_ref,
         txsrc_ref, txw_ref, txseg_ref, txprn_ref,
         out_ref, resid_ref, val_ref, newlast_ref, nrel_ref, sends_ref,
         prev_ref, fcur_ref, flag_ref, rcount_ref) = refs
        mxct_ref = mxpos_ref = mxdst_ref = mxval_ref = None
    else:
        (mxct_ref, rxct_ref, txct_ref,
         dist_ref, front_ref, live_ref, inc_ref, last_ref, svalid_ref,
         mxpos_ref, mxdst_ref, mxval_ref,
         rxsrc_ref, rxw_ref, rxdst_ref, rxprn_ref,
         txsrc_ref, txw_ref, txseg_ref, txprn_ref,
         out_ref, resid_ref, val_ref, newlast_ref, nrel_ref, sends_ref,
         prev_ref, fcur_ref, flag_ref, rcount_ref) = refs

    s = pl.program_id(0)
    c = pl.program_id(1)
    S = n_sweeps
    first = (s == 0) & (c == 0)
    last = (s == S + 1) & (c == grid_c - 1)
    live_col = live_ref[...][:, None] > 0             # [K, 1]

    @pl.when(first)
    def _init():
        for k in range(n_queries):
            rcount_ref[k] = 0

    # ---- stage 0: merge delivered messages, derive the frontier ----
    if dense:
        @pl.when(first)
        def _merge_dense():
            out_ref[...] = jnp.minimum(dist_ref[...], inc_ref[...])
    else:
        @pl.when(first)
        def _init_merge():
            out_ref[...] = dist_ref[...]

        @pl.when((s == 0) & (c < mx_chunks))
        def _merge_chunk():
            t = jnp.minimum(mxct_ref[c], n_vtiles - 1)
            vtile = pl.dslice(t * vb, vb)
            pos = mxpos_ref[0, :]                 # [EB] int32 (padding = 0)
            dstrel = mxdst_ref[0, :]              # [EB] int32 in [0, vb)
            valid = mxval_ref[0, :] > 0
            v = jnp.take(inc_ref[...], pos, axis=1)       # [K, EB]
            cand = jnp.where(valid[None, :], v, INF)
            mins = tile_min_batch(cand, dstrel, width=vb)
            out_ref[:, vtile] = jnp.minimum(out_ref[:, vtile], mins)

    # stage-end bookkeeping: global frontier + sweep snapshot
    @pl.when((s == 0) & (c == grid_c - 1))
    def _merge_done():
        newf = (out_ref[...] < dist_ref[...]) & live_col
        fcur_ref[...] = jnp.maximum(newf.astype(jnp.float32), front_ref[...])
        prev_ref[...] = out_ref[...]
        flag_ref[0] = jnp.any(fcur_ref[...] > 0).astype(jnp.int32)

    # ---- stages 1..S: frontier-chased relaxation sweeps ----
    r_stage = (s >= 1) & (s <= S)

    @pl.when(r_stage & (s > 1) & (c == 0) & (flag_ref[0] > 0))
    def _advance_sweep():
        newf = (out_ref[...] < prev_ref[...]).astype(jnp.float32)
        fcur_ref[...] = newf
        flag_ref[0] = jnp.any(newf > 0).astype(jnp.int32)
        prev_ref[...] = out_ref[...]

    @pl.when(r_stage & (c < rx_chunks) & (flag_ref[0] > 0))
    def _relax_chunk():
        t = jnp.minimum(rxct_ref[c], n_vtiles - 1)
        vtile = pl.dslice(t * vb, vb)
        src = rxsrc_ref[0, :]                     # [EB] (padding = bp - 1)
        w = jnp.where(rxprn_ref[0, :] > 0, INF, rxw_ref[0, :])
        dstrel = rxdst_ref[0, :]
        f_src = jnp.take(fcur_ref[...], src, axis=1) > 0  # [K, EB]
        d_src = jnp.take(out_ref[...], src, axis=1)       # Gauss–Seidel
        cand = jnp.where(f_src, d_src + w[None, :], INF)
        sums = jnp.sum(f_src & (w < INF)[None, :], axis=1).astype(jnp.int32)
        for k in range(n_queries):
            rcount_ref[k] = rcount_ref[k] + sums[k]
        mins = tile_min_batch(cand, dstrel, width=vb)
        out_ref[:, vtile] = jnp.minimum(out_ref[:, vtile], mins)

    # ---- stage S + 1: send-pack against last_sent ----
    @pl.when((s == S + 1) & (c == 0))
    def _init_send():
        val_ref[...] = jnp.full(val_ref.shape, INF, jnp.float32)

    @pl.when((s == S + 1) & (c < tx_chunks))
    def _send_chunk():
        t = jnp.minimum(txct_ref[c], n_stiles - 1)
        stile = pl.dslice(t * sb, sb)
        src = txsrc_ref[0, :]                     # [EB] (padding = 0)
        w = jnp.where(txprn_ref[0, :] > 0, INF, txw_ref[0, :])
        segrel = txseg_ref[0, :]
        d_src = jnp.take(out_ref[...], src, axis=1)
        cand = d_src + w[None, :]
        mins = tile_min_batch(cand, segrel, width=sb)
        val_ref[:, stile] = jnp.minimum(val_ref[:, stile], mins)

    @pl.when(last)
    def _fin():
        val = val_ref[...]                        # [K, S_pad]
        prevl = last_ref[...]
        valid = svalid_ref[...][None, :] > 0
        improved = valid & (val < prevl)
        val_ref[...] = jnp.where(improved, val, INF)
        newlast_ref[...] = jnp.where(improved, val, prevl)
        ssums = jnp.sum(improved, axis=1).astype(jnp.int32)
        resid_ref[...] = (out_ref[...] < prev_ref[...]).astype(jnp.float32)
        for k in range(n_queries):
            nrel_ref[k] = rcount_ref[k]
            sends_ref[k] = ssums[k]


def _stage_map_ragged(lo: int, hi: int, nc: int):
    """Ragged stage index map: clamp the flat chunk while the stage is
    active, pin to block (0, 0) otherwise. Scalar-prefetch refs arrive as
    trailing args and are unused here — the CHUNK index is the block index;
    the tile lives in the kernel-side map."""
    def m(s, c, *_):
        ok = (s >= lo) & (s <= hi)
        return jnp.where(ok, jnp.minimum(c, nc - 1), 0), 0
    return m


def fused_round_ragged(dist_pad, front_pad, live, incoming, last_pad,
                       valid_pad, mx_layout, rx_layout, tx_layout, *,
                       vb: int, sb: int, n_sweeps: int, dense: bool,
                       interpret: bool = True):
    """One fused round over ragged CSR-chunked layouts.

    Same contract as ``fused_round_tiled`` except each layout tuple gains
    its chunk→tile map: rx/tx_layout = (src_r, w_r, *, pruned_r, ctile)
    with flat [total_chunks, EB] rows; mx_layout = (pos_r, dstrel_r,
    valid_r, ctile) or None when dense."""
    rx_src, rx_w, rx_dst, rx_prn, rx_ct = rx_layout
    tx_src, tx_w, tx_seg, tx_prn, tx_ct = tx_layout
    rx_chunks, rx_eb = rx_src.shape
    tx_chunks, tx_eb = tx_src.shape
    nq, bp = dist_pad.shape
    sp = last_pad.shape[1]
    assert bp % vb == 0 and sp % sb == 0 and last_pad.shape == (nq, sp)
    n_vtiles = bp // vb
    n_stiles = sp // sb
    S = n_sweeps

    if dense:
        assert incoming.shape == (nq, bp)
        mx_chunks = 1
        scalars = (rx_ct, tx_ct)
    else:
        mx_pos, mx_dst, mx_val, mx_ct = mx_layout
        mx_chunks, mx_eb = mx_pos.shape
        scalars = (mx_ct, rx_ct, tx_ct)

    grid_c = max(rx_chunks, tx_chunks, mx_chunks if not dense else 1)
    grid = (S + 2, grid_c)

    dist_spec = pl.BlockSpec((nq, bp), lambda s, c, *_: (0, 0))
    slot_spec = pl.BlockSpec((nq, sp), lambda s, c, *_: (0, 0))
    q_spec = pl.BlockSpec((nq,), lambda s, c, *_: (0,))
    rx_spec = pl.BlockSpec((1, rx_eb), _stage_map_ragged(1, S, rx_chunks))
    tx_spec = pl.BlockSpec((1, tx_eb), _stage_map_ragged(S + 1, S + 1,
                                                         tx_chunks))

    in_specs = [dist_spec, dist_spec, q_spec]
    operands = [dist_pad, front_pad, live]
    if dense:
        in_specs += [dist_spec]
    else:
        in_specs += [pl.BlockSpec(incoming.shape, lambda s, c, *_: (0, 0))]
    operands += [incoming]
    in_specs += [slot_spec, pl.BlockSpec((sp,), lambda s, c, *_: (0,))]
    operands += [last_pad, valid_pad]
    if not dense:
        mx_spec = pl.BlockSpec((1, mx_eb), _stage_map_ragged(0, 0, mx_chunks))
        in_specs += [mx_spec, mx_spec, mx_spec]
        operands += [mx_pos, mx_dst, mx_val]
    in_specs += [rx_spec] * 4 + [tx_spec] * 4
    operands += [rx_src, rx_w, rx_dst, rx_prn, tx_src, tx_w, tx_seg, tx_prn]

    kernel = functools.partial(
        _fused_round_ragged_kernel, dense=dense, vb=vb, sb=sb,
        n_vtiles=n_vtiles, n_stiles=n_stiles, rx_chunks=rx_chunks, tx_chunks=tx_chunks, mx_chunks=mx_chunks,
        n_sweeps=S, n_queries=nq, grid_c=grid_c)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=len(scalars),
        grid=grid,
        in_specs=in_specs,
        out_specs=[
            dist_spec,            # merged + relaxed distances
            dist_spec,            # residual frontier of the final sweep
            slot_spec,            # masked send values
            slot_spec,            # updated last_sent
            q_spec,               # per-query relaxations
            q_spec,               # per-query sends
        ],
        scratch_shapes=[
            pltpu.VMEM((nq, bp), jnp.float32),    # prev (sweep snapshot)
            pltpu.VMEM((nq, bp), jnp.float32),    # current frontier
            pltpu.SMEM((1,), jnp.int32),          # global early-out flag
            pltpu.SMEM((nq,), jnp.int32),         # relaxation counters
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((nq, bp), dist_pad.dtype),
            jax.ShapeDtypeStruct((nq, bp), jnp.float32),
            jax.ShapeDtypeStruct((nq, sp), jnp.float32),
            jax.ShapeDtypeStruct((nq, sp), jnp.float32),
            jax.ShapeDtypeStruct((nq,), jnp.int32),
            jax.ShapeDtypeStruct((nq,), jnp.int32),
        ],
        interpret=interpret,
    )(*scalars, *operands)
