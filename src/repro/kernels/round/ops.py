"""jit'd wrappers for the fused round megakernel.

``fused_round_pallas`` pads the solver-facing state into tile-aligned
buffers, gathers the Trishla pruned mask into both tiled edge orders, and
runs the megakernel. It deliberately does NOT resolve the residual
frontier: the caller inspects ``resid`` and — only when some query's
fixpoint escaped ``n_sweeps`` in-kernel sweeps — runs
``fused_round_rescue``, which finishes the relaxation with the batched
relax kernel and re-packs the sends against the ORIGINAL ``last_sent``
(the megakernel's send outputs were computed from unconverged distances
and are discarded wholesale). Keeping the rescue outside lets the solver
wrap it in a ``lax.cond`` whose predicate is reduced over the whole shard
stack, so the common all-converged round never pays for it.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.relax import (
    relax_fixpoint_batch_pallas, relax_fixpoint_batch_ragged_pallas,
)
from repro.kernels.round.round import fused_round_ragged, fused_round_tiled
from repro.kernels.send.send import send_pack_ragged, send_pack_tiled

INF = float("inf")


def _pad_state(dist, front_in, live, last_sent, slot_valid, *, bp, sp):
    nq, block = dist.shape
    n_slots = last_sent.shape[1]
    dist_pad = jnp.full((nq, bp), INF, jnp.float32).at[:, :block].set(dist)
    front_pad = (jnp.zeros((nq, bp), jnp.float32)
                 .at[:, :block].set(front_in.astype(jnp.float32)))
    last_pad = (jnp.full((nq, sp), INF, jnp.float32)
                .at[:, :n_slots].set(last_sent))
    valid_pad = (jnp.zeros((sp,), jnp.int32)
                 .at[:n_slots].set(slot_valid.astype(jnp.int32)))
    return dist_pad, front_pad, live.astype(jnp.float32), last_pad, valid_pad


def _gather_pruned(pruned, eid_t):
    return jnp.take(pruned.astype(jnp.int32), eid_t, mode="fill",
                    fill_value=0)


@partial(jax.jit, static_argnames=("vb", "sb", "n_sweeps", "dense",
                                   "interpret"))
def fused_round_pallas(dist, front_in, live, incoming, last_sent, slot_valid,
                       relax_layout, send_layout, merge_layout, pruned_loc,
                       pruned_cut, *, vb: int = 128, sb: int = 128,
                       n_sweeps: int = 8, dense: bool = False,
                       interpret: bool = True):
    """One fused merge + local-fixpoint + send-pack round on one shard.

    dist/front_in: [K, block]; live: [K] bool; incoming: [K, M] flattened
    bucket messages or [K, block] dense remote minima; last_sent/slot_valid:
    [K, S] / [S]; relax_layout/send_layout: the shard's 4-tuple tiled edge
    layouts (src, w, rel, eid); merge_layout: (pos, dstrel, valid) msg-tiled
    layout (ignored when dense); pruned_loc/pruned_cut: [e_loc] / [e_cut]
    Trishla masks in original edge order.

    Returns (new_dist [K, block], send_val [K, S], new_last [K, S],
    nrel [K], sends [K], resid [K, block] f32 — non-empty rows mean the
    in-kernel sweeps did not converge and the caller must rescue).

    Ragged (CSR-chunked) shards pass 5-tuple relax/send layouts (flat
    chunk rows + chunk→tile map) and a 4-tuple merge layout; the tuple
    arity selects the ragged megakernel."""
    ragged = len(relax_layout) == 5
    if ragged:
        rx_src, rx_w, rx_dst, rx_eid, rx_ct = relax_layout
        tx_src, tx_w, tx_seg, tx_eid, tx_ct = send_layout
    else:
        rx_src, rx_w, rx_dst, rx_eid = relax_layout
        tx_src, tx_w, tx_seg, tx_eid = send_layout
    nq, block = dist.shape
    n_slots = last_sent.shape[1]
    if ragged:
        bp = max(-(-block // vb), 1) * vb
        sp = max(-(-n_slots // sb), 1) * sb
    else:
        bp = rx_src.shape[0] * vb
        sp = tx_src.shape[0] * sb

    dist_pad, front_pad, live_f, last_pad, valid_pad = _pad_state(
        dist, front_in, live, last_sent, slot_valid, bp=bp, sp=sp)
    rx = (rx_src, rx_w, rx_dst, _gather_pruned(pruned_loc, rx_eid))
    tx = (tx_src, tx_w, tx_seg, _gather_pruned(pruned_cut, tx_eid))
    if ragged:
        rx = rx + (rx_ct,)
        tx = tx + (tx_ct,)
    if dense:
        inc = jnp.full((nq, bp), INF, jnp.float32).at[:, :block].set(incoming)
        mx = None
    else:
        inc = incoming
        mx = merge_layout

    round_fn = fused_round_ragged if ragged else fused_round_tiled
    out, resid, sval, nlast, nrel, sends = round_fn(
        dist_pad, front_pad, live_f, inc, last_pad, valid_pad, mx, rx, tx,
        vb=vb, sb=sb, n_sweeps=n_sweeps, dense=dense, interpret=interpret)
    return (out[:, :block], sval[:, :n_slots], nlast[:, :n_slots], nrel,
            sends, resid[:, :block])


@partial(jax.jit, static_argnames=("vb", "sb", "n_sweeps", "max_iters",
                                   "interpret"))
def fused_round_rescue(dist, resid, last_sent, slot_valid, relax_layout,
                       send_layout, pruned_loc, pruned_cut, *, vb: int = 128,
                       sb: int = 128, n_sweeps: int = 8,
                       max_iters: int = 10_000, interpret: bool = True):
    """Finish a round whose in-kernel sweeps left a residual frontier.

    ``dist``/``resid`` are the megakernel's merged-and-partially-relaxed
    distances and its final-sweep residual. Continues the fixpoint with the
    batched relax kernel (iteration budget starts at ``n_sweeps``, exactly
    like the staged pipeline's outer loop) and re-packs the sends against
    the original ``last_sent``. Returns (new_dist [K, block],
    send_val [K, S], new_last [K, S], nrel_extra [K], sends [K])."""
    ragged = len(relax_layout) == 5
    if ragged:
        rx_src, rx_w, rx_dst, rx_eid, rx_ct = relax_layout
        tx_src, tx_w, tx_seg, tx_eid, tx_ct = send_layout
    else:
        rx_src, rx_w, rx_dst, rx_eid = relax_layout
        tx_src, tx_w, tx_seg, tx_eid = send_layout
    rx_eb = rx_src.shape[-1]
    tx_eb = tx_src.shape[-1]
    nq, block = dist.shape
    n_slots = last_sent.shape[1]
    if ragged:
        bp = max(-(-block // vb), 1) * vb
        sp = max(-(-n_slots // sb), 1) * sb
    else:
        bp = rx_src.shape[0] * vb
        sp = tx_src.shape[0] * sb

    dist_pad, front_pad, _, last_pad, valid_pad = _pad_state(
        dist, resid, jnp.ones((nq,), bool), last_sent, slot_valid, bp=bp,
        sp=sp)
    prn_rx = _gather_pruned(pruned_loc, rx_eid)
    prn_tx = _gather_pruned(pruned_cut, tx_eid)

    def cond(c):
        _, front, _, it = c
        return jnp.any(front > 0) & (it < max_iters)

    def body(c):
        d, front, n, it = c
        if ragged:
            nd, rs, k = relax_fixpoint_batch_ragged_pallas(
                d, front, rx_ct, rx_src, rx_w, rx_dst, prn_rx, vb=vb,
                eb=rx_eb, n_sweeps=n_sweeps, interpret=interpret)
        else:
            nd, rs, k = relax_fixpoint_batch_pallas(
                d, front, rx_src, rx_w, rx_dst, prn_rx, vb=vb, eb=rx_eb,
                n_sweeps=n_sweeps, interpret=interpret)
        return nd, rs, n + k, it + jnp.int32(n_sweeps)

    d2, _, nrel_extra, _ = jax.lax.while_loop(
        cond, body, (dist_pad, front_pad, jnp.zeros((nq,), jnp.int32),
                     jnp.int32(n_sweeps)))
    if ragged:
        sval, nlast, sends = send_pack_ragged(
            d2, last_pad, valid_pad, tx_ct, tx_src, tx_w, tx_seg, prn_tx,
            sb=sb, eb=tx_eb, interpret=interpret)
    else:
        sval, nlast, sends = send_pack_tiled(
            d2, last_pad, valid_pad, tx_src, tx_w, tx_seg, prn_tx, sb=sb,
            eb=tx_eb, interpret=interpret)
    return (d2[:, :block], sval[:, :n_slots], nlast[:, :n_slots], nrel_extra,
            sends)
