from repro.kernels.round.ops import fused_round_pallas, fused_round_rescue
from repro.kernels.round.ref import fused_round_ref
