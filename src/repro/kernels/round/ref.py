"""Pure-jnp oracle for one fused SP-Async round (interpret-mode testing).

Replays the round as the staged pipeline would: scatter-min merge of the
delivered messages, frontier derivation, Jacobi Bellman–Ford local
fixpoint, then the segment-min send pack against ``last_sent``. The
relaxation COUNT is sweep-schedule dependent (Jacobi here vs Gauss–Seidel
in the kernel) and is deliberately not part of the oracle contract — the
fixpoint itself is solver-independent, so distances and send outputs are
bit-comparable. End-to-end count identity with the staged pallas pipeline
is enforced by the solver-level tests instead.

Self-contained (jnp only, no ``repro.core`` imports) so it can be used
from kernel-layer tests without pulling in the solver.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

INF = float("inf")


def _local_fixpoint(dist, front, loc_src, loc_dst, loc_w, max_iters):
    """Jacobi Bellman–Ford to fixpoint for one query row."""
    def cond(c):
        _, f, it = c
        return jnp.any(f) & (it < max_iters)

    def body(c):
        d, f, it = c
        ok = jnp.take(f, loc_src, mode="fill", fill_value=False)
        d_src = jnp.take(d, loc_src, mode="fill", fill_value=INF)
        cand = jnp.where(ok, d_src + loc_w, INF)
        new = d.at[loc_dst].min(cand, mode="drop")
        return new, new < d, it + 1

    return jax.lax.while_loop(cond, body, (dist, front, jnp.int32(0)))[0]


def fused_round_ref(dist, front_in, live, incoming, recv_idx, last_sent,
                    slot_valid, loc_src, loc_dst, loc_w, pruned_loc, cut_src,
                    cut_seg, cut_w, pruned_cut, *, dense: bool = False,
                    max_iters: int = 10_000):
    """dist/front_in: [K, block]; live: [K] bool; incoming: [K, M] flat
    bucket messages (with ``recv_idx`` [M] flat targets, sentinel = block)
    or [K, block] dense remote minima (recv_idx ignored); last_sent /
    slot_valid: [K, S] / [S]; loc_* / cut_*: original-order edge lists;
    pruned_*: bool masks. Returns (new_dist [K, block], send_val [K, S],
    new_last [K, S], sends [K] i32)."""
    nq, block = dist.shape
    n_slots = last_sent.shape[1]

    if dense:
        merged = jnp.minimum(dist, incoming)
    else:
        flat = incoming.reshape(nq, -1)
        idx = recv_idx.reshape(-1)
        merged = jax.vmap(
            lambda d, v: d.at[idx].min(v, mode="drop"))(dist, flat)
    front = ((merged < dist) & live[:, None]) | front_in

    w_loc = jnp.where(pruned_loc, INF, loc_w)
    new_dist = jax.vmap(
        lambda d, f: _local_fixpoint(d, f, loc_src, loc_dst, w_loc,
                                     max_iters))(merged, front)

    w_cut = jnp.where(pruned_cut, INF, cut_w)
    d_src = jnp.take(new_dist, cut_src, axis=1, mode="fill", fill_value=INF)
    cand = d_src + w_cut[None, :]
    slot_val = jax.vmap(lambda c: jax.ops.segment_min(
        c, cut_seg, num_segments=n_slots, indices_are_sorted=True))(cand)
    improved = slot_valid[None, :] & (slot_val < last_sent)
    send_val = jnp.where(improved, slot_val, INF)
    new_last = jnp.where(improved, slot_val, last_sent)
    sends = jnp.sum(improved, axis=1).astype(jnp.int32)
    return new_dist, send_val, new_last, sends
