"""jit'd wrappers for embedding-bag."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.embedding_bag.embedding_bag import embedding_bag_p


@partial(jax.jit, static_argnames=("mode", "bb", "interpret"))
def embedding_bag(table, indices, *, mode: str = "sum", bb: int = 8,
                  interpret: bool = True):
    """Pallas path. Pads the bag axis to a multiple of ``bb``."""
    B, L = indices.shape
    pad = (-B) % bb
    if pad:
        indices = jnp.concatenate(
            [indices, jnp.full((pad, L), table.shape[0], indices.dtype)])
    out = embedding_bag_p(table, indices, mode=mode, bb=bb, interpret=interpret)
    return out[:B]


@partial(jax.jit, static_argnames=("mode",))
def embedding_bag_jnp(table, indices, *, mode: str = "sum"):
    """XLA path (take + masked sum) — used by the AutoInt model at scale."""
    V = table.shape[0]
    valid = indices < V
    rows = jnp.take(table, indices, axis=0, mode="fill", fill_value=0.0)
    rows = jnp.where(valid[..., None], rows, 0.0)
    out = jnp.sum(rows, axis=1)
    if mode == "mean":
        cnt = jnp.maximum(jnp.sum(valid, axis=1, keepdims=True), 1)
        out = out / cnt.astype(out.dtype)
    return out
