"""Pallas TPU kernel: embedding-bag (ragged gather + in-register reduce).

TPU adaptation: GPU embedding bags are warp-per-bag gathers; the TPU
equivalent streams the *bag* axis through the grid while the table stays in
HBM (``memory_space=ANY``) and each row is fetched as a 1-row dynamic slice
(lowers to a DMA per row — the memory-bound reality of embedding lookup;
a production deployment would double-buffer these DMAs). The per-bag L
accumulation happens in VMEM registers.

Grid: ``(n_bag_tiles,)``; per step: indices tile [BB, L] from SMEM-friendly
int32, output tile [BB, D].
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _embag_kernel(idx_ref, table_ref, out_ref, *, bb: int, L: int, mean: bool):
    V, D = table_ref.shape
    acc = jnp.zeros((bb, D), jnp.float32)
    cnt = jnp.zeros((bb,), jnp.float32)
    for b in range(bb):          # static unroll: one bag per sublane group
        row_acc = jnp.zeros((1, D), jnp.float32)
        c = jnp.float32(0)
        for l in range(L):
            ix = idx_ref[b, l]
            valid = ix < V
            safe = jnp.where(valid, ix, 0)
            row = table_ref[pl.dslice(safe, 1), :]
            row_acc = row_acc + jnp.where(valid, row.astype(jnp.float32), 0.0)
            c = c + jnp.where(valid, 1.0, 0.0)
        acc = acc.at[b].set(row_acc[0])
        cnt = cnt.at[b].set(c)
    if mean:
        acc = acc / jnp.maximum(cnt, 1.0)[:, None]
    out_ref[...] = acc.astype(out_ref.dtype)


def embedding_bag_p(table, indices, *, mode: str = "sum", bb: int = 8,
                    interpret: bool = True):
    """table: [V, D]; indices: [B, L] (B % bb == 0). Returns [B, D]."""
    B, L = indices.shape
    V, D = table.shape
    grid = (B // bb,)
    kernel = functools.partial(_embag_kernel, bb=bb, L=L, mean=(mode == "mean"))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, L), lambda i: (i, 0)),
            pl.BlockSpec(memory_space=pl.ANY),        # whole table in HBM
        ],
        out_specs=pl.BlockSpec((bb, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, D), table.dtype),
        interpret=interpret,
    )(indices, table)
