"""Pure-jnp oracle for fixed-length embedding-bag (sum / mean).

JAX has no native EmbeddingBag; the reference composes take + masked sum.
``indices`` use ``vocab`` as the padding sentinel.
"""
from __future__ import annotations

import jax.numpy as jnp


def embedding_bag_ref(table, indices, *, mode: str = "sum"):
    """table: [V, D]; indices: [B, L] int32 (V = padding). Returns [B, D]."""
    V = table.shape[0]
    valid = indices < V
    rows = jnp.take(table, indices, axis=0, mode="fill", fill_value=0.0)
    rows = jnp.where(valid[..., None], rows, 0.0)
    out = jnp.sum(rows, axis=1)
    if mode == "mean":
        cnt = jnp.maximum(jnp.sum(valid, axis=1, keepdims=True), 1)
        out = out / cnt.astype(out.dtype)
    return out
