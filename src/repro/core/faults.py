"""Fault injection for the SP-Async exchange — the paper's robustness claim
made executable.

The paper argues the asynchronous mode is safe because the scatter-min
merge is monotone and idempotent: a dropped, delayed, duplicated, or
reordered message can change *round counts* but never the fixpoint. Until
this module nothing exercised that claim — every exchange delivered every
payload, in order, exactly once. :class:`FaultPlan` describes a message
failure model and :func:`wrap_exchange` decorates any resolved
``ExchangeStage`` backend (``bucket`` / ``pmin`` / ``a2a_dense``) with a
*receiver-side* injector, so any existing pipeline runs under faults via
``SsspConfig(faults=FaultPlan(...))`` on both the sim and shmap backends.

Fault model (per message position, per round, receiver side)
------------------------------------------------------------
Randomness is a deterministic ``jax.random`` stream: one key per
``(config seed, round, receiving shard)`` via ``fold_in``, so a seeded run
replays bit-exactly on either backend. Each *finite* incoming value draws
one uniform and lands in exactly one regime:

- ``drop``      — the message is lost. If it would have improved the
  receiver (``val < dist[target]``) the loss *matters* and is tracked in
  ``unhealed`` until the next anti-entropy resend retransmits every
  ``last_sent`` minimum (see ``FaultPlan.resend_period`` and the resend
  wiring in ``core/sssp.py``). Harmless drops (stale values) are forgotten.
- ``delay``     — the message is withheld and enqueued into a *bounded
  in-carry queue* (depth ``max_delay``) at a random slot; it re-merges
  1..max_delay rounds later, exercising the stale-merge path for real.
- ``duplicate`` — the message is delivered now AND a copy is enqueued, so
  the same value merges again later (idempotence under late duplicates).
- ``reorder``   — the message is withheld and enqueued at the head slot:
  it arrives one round late, *after* messages sent a round later
  (out-of-order delivery under the commutative merge).

The queue's oldest slot is released every round and min-merged with the
fresh deliveries — position ``m`` always addresses the same destination
vertex, so the release IS a stale scatter-min merge. ``pending`` reports,
per query, whether this shard still holds undelivered state (non-empty
queue, or an unhealed mattering drop when anti-entropy is on): the round
feeds it into the termination stage so no detector can declare quiescence
over in-flight messages.

Injection is on the *receiving* side of the collective: for the dense
exchanges the transferred payload is already reduced over senders, so a
fault there models losing the combined update — the same observable a
receiver-side loss produces on a real transport.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

INF = jnp.float32(jnp.inf)

_PROBS = ("drop", "delay", "duplicate", "reorder")


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Message failure model + recovery knobs (hashable: rides in
    ``SsspConfig`` and therefore in every engine/jit cache key).

    ``drop``/``delay``/``duplicate``/``reorder`` are per-message
    probabilities (disjoint regimes; their sum must be <= 1). ``seed``
    roots the deterministic per-round `jax.random` stream. ``max_delay``
    bounds the in-carry delay queue (a delayed message re-merges within
    that many rounds). ``resend_period > 0`` enables anti-entropy: every
    N-th round senders retransmit ALL their ``last_sent`` minima, so a
    dropped improvement is provably healed instead of accidentally masked
    — with ``resend_period=0`` drops are permanent and the engine's
    fixpoint certificate reports the solve as ``degraded``."""

    drop: float = 0.0
    delay: float = 0.0
    duplicate: float = 0.0
    reorder: float = 0.0
    seed: int = 0
    max_delay: int = 3
    resend_period: int = 0

    def __post_init__(self):
        for name in _PROBS:
            p = float(getattr(self, name))
            if not 0.0 <= p <= 1.0:
                raise ValueError(f"FaultPlan.{name}={p!r} must be in [0, 1]")
        total = sum(float(getattr(self, n)) for n in _PROBS)
        if total > 1.0:
            raise ValueError(
                f"FaultPlan probabilities sum to {total:.3f} > 1 (each "
                "message lands in exactly one fault regime)")
        if self.max_delay < 1:
            raise ValueError("FaultPlan.max_delay must be >= 1")
        if self.resend_period < 0:
            raise ValueError("FaultPlan.resend_period must be >= 0")

    @property
    def active(self) -> bool:
        """Any fault probability non-zero (an all-zero plan is the
        fault-free pipeline; no carry state or RNG is threaded)."""
        return any(float(getattr(self, n)) > 0.0 for n in _PROBS)

    @property
    def fault_slack(self) -> int:
        """Extra rounds the toka3 timeout must absorb: a message can hide
        in the delay queue for ``max_delay`` rounds, and a mattering drop
        is only guaranteed healed ``resend_period`` rounds later."""
        return int(self.max_delay) + int(self.resend_period)


class FaultState(NamedTuple):
    """Per-shard in-carry fault state.

    ``queue[d, k, m]`` holds a withheld message value for query ``k`` at
    flat payload position ``m``, due for release in ``d + 1`` rounds
    (+inf = empty). ``unhealed[k]`` latches a dropped message that would
    have improved the receiver, until the next anti-entropy resend."""
    queue: Any      # [D, K, M] f32 (sim stacks a leading [P])
    unhealed: Any   # [K] bool


def init_state(plan: FaultPlan, nq: int, n_msgs: int,
               n_parts: int | None = None) -> FaultState:
    """Empty fault state; ``n_parts`` prepends the stacked sim axis."""
    lead = () if n_parts is None else (n_parts,)
    return FaultState(
        queue=jnp.full(lead + (plan.max_delay, nq, n_msgs), INF, jnp.float32),
        unhealed=jnp.zeros(lead + (nq,), bool))


def inject(plan: FaultPlan, incoming, d_target, state: FaultState, key):
    """One round of receiver-side faults over flattened messages [K, M].

    ``d_target[k, m]`` is the receiver's current distance at message m's
    destination vertex (+inf for unaddressed positions) — it decides
    whether a dropped message *mattered* and whether a released stale
    message still counts as a real (improving) stale merge.

    Returns ``(delivered [K, M], state', stale [K] i32, pending [K] bool)``
    where ``delivered`` already min-merges this round's queue release.
    """
    kmode, kslot = jax.random.split(key)
    u = jax.random.uniform(kmode, incoming.shape)
    finite = jnp.isfinite(incoming)
    p0 = plan.drop
    p1 = p0 + plan.delay
    p2 = p1 + plan.duplicate
    p3 = p2 + plan.reorder
    m_drop = finite & (u < p0)
    m_delay = finite & (p0 <= u) & (u < p1)
    m_dup = finite & (p1 <= u) & (u < p2)
    m_reorder = finite & (p2 <= u) & (u < p3)

    now = jnp.where(m_drop | m_delay | m_reorder, INF, incoming)

    # release the oldest queue slot, age the rest, enqueue this round's
    # delayed/duplicated/reordered values (delay draws a random slot;
    # duplicate and reorder land at the head = next round)
    D = state.queue.shape[0]
    release = state.queue[0]
    aged = jnp.concatenate(
        [state.queue[1:], jnp.full_like(state.queue[:1], INF)])
    slot = jnp.where(m_delay, jax.random.randint(kslot, incoming.shape, 0, D),
                     0)
    enq = m_delay | m_dup | m_reorder
    onehot = (slot[None] == jnp.arange(D)[:, None, None]) & enq[None]
    queue = jnp.minimum(aged, jnp.where(onehot, incoming[None], INF))

    delivered = jnp.minimum(now, release)
    stale = jnp.sum(jnp.isfinite(release) & (release < d_target),
                    axis=-1).astype(jnp.int32)
    # a lost message matters only while it would still improve the
    # receiver — dist is monotone non-increasing, so once it stops
    # mattering it never matters again
    lost = m_drop & (incoming < d_target)
    unhealed = state.unhealed | jnp.any(lost, axis=-1)
    pending = jnp.any(jnp.isfinite(queue), axis=(0, -1))
    if plan.resend_period > 0:
        # anti-entropy will heal the drop: hold termination open for it.
        # With no resend the drop is permanent — terminating is the only
        # honest option, and the engine's certificate flags it degraded.
        pending = pending | unhealed
    return delivered, FaultState(queue=queue, unhealed=unhealed), stale, pending


class FaultyExchange(NamedTuple):
    """An ``ExchangeStage`` decorated with fault delivery: ``run`` is the
    untouched transfer (duck-type compatible with the plain stage);
    ``deliver`` is the per-shard injector the round applies to whatever
    ``run`` produced, threading the in-carry :class:`FaultState`. The
    deferred-exchange protocol fields (``deferred``/``recv``/``push``/
    ``init_inflight``/``flush``) pass through untouched: under an async
    exchange the injector applies at DELIVERY time — when a lagged batch
    leaves the in-flight buffer — so faults + anti-entropy resend compose
    with the one-round lag unchanged (a resent copy simply rides the pipe
    and heals ``lag`` rounds later; the in-flight pending bits hold every
    detector open in the meantime)."""
    name: str
    dense: bool
    run: Any
    plan: FaultPlan
    deliver: Any    # (shard, dist, incoming, state, key) -> (inc', st', stale, pending)
    deferred: bool = False
    recv: Any = None
    push: Any = None
    init_inflight: Any = None
    flush: Any = None


def wrap_exchange(stage, plan: FaultPlan) -> FaultyExchange:
    """Decorate a resolved exchange backend (bucket / pmin / a2a_dense /
    async / async_bucket / async_ppermute) with receiver-side fault
    injection under ``plan``.

    The payload *kind* follows the stage's ``dense`` flag: dense incoming
    is already owner-addressed ``[K, block]`` (``d_target`` is the local
    distance row itself); bucketed incoming flattens ``[K, P, C]`` to
    message positions whose targets come from the static ``recv_idx``
    routing table."""

    if stage.dense:
        def deliver(sh, dist, incoming, state, key):
            return inject(plan, incoming, dist, state, key)
    else:
        def deliver(sh, dist, incoming, state, key):
            nq = incoming.shape[0]
            flat = incoming.reshape(nq, -1)
            tgt = sh.recv_idx.reshape(-1)   # sentinel = block -> fill +inf
            d_t = jnp.take(dist, tgt, axis=1, mode="fill",
                           fill_value=float("inf"))
            out, st, stale, pending = inject(plan, flat, d_t, state, key)
            return out.reshape(incoming.shape), st, stale, pending

    return FaultyExchange(name=f"{stage.name}+faults", dense=stage.dense,
                          run=stage.run, plan=plan, deliver=deliver,
                          deferred=getattr(stage, "deferred", False),
                          recv=getattr(stage, "recv", None),
                          push=getattr(stage, "push", None),
                          init_inflight=getattr(stage, "init_inflight", None),
                          flush=getattr(stage, "flush", None))
