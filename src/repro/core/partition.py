"""1-D block graph partitioning (paper §III.A).

``Pid(v) = v // block`` with ``block = ceil(N / P)`` — each process keeps a
non-empty adjacency list only for its own vertices, matching the paper's
``Padj`` construction. Host-side numpy; one-time cost ("Graph Partition"
phase in the paper's cost model).
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.graph.structure import Graph, PartitionedGraph, graph_to_numpy


def partition_1d(g: Graph, n_parts: int, e_max: int | None = None) -> PartitionedGraph:
    src, dst, w = graph_to_numpy(g)
    n = g.n_vertices
    block = -(-n // n_parts)  # ceil
    owner = (src // block).astype(np.int64)
    counts = np.bincount(owner, minlength=n_parts)
    if e_max is None:
        e_max = max(int(counts.max()) if len(counts) else 1, 1)
    assert e_max >= counts.max(), (e_max, counts.max())

    P = n_parts
    src_local = np.full((P, e_max), block, np.int64)       # sentinel local id
    dst_global = np.full((P, e_max), n, np.int64)
    dst_owner = np.zeros((P, e_max), np.int64)
    dst_local = np.full((P, e_max), block, np.int64)
    weight = np.full((P, e_max), np.inf, np.float32)
    valid = np.zeros((P, e_max), bool)

    order = np.argsort(owner, kind="stable")
    s, d, ww, own = src[order], dst[order], w[order], owner[order]
    starts = np.zeros(P + 1, np.int64)
    np.add.at(starts, own + 1, 1)
    starts = np.cumsum(starts)
    for p in range(P):
        lo, hi = starts[p], starts[p + 1]
        k = hi - lo
        src_local[p, :k] = s[lo:hi] - p * block
        dst_global[p, :k] = d[lo:hi]
        dst_owner[p, :k] = d[lo:hi] // block
        dst_local[p, :k] = d[lo:hi] - dst_owner[p, :k] * block
        weight[p, :k] = ww[lo:hi]
        valid[p, :k] = True

    part_ids = np.arange(P)[:, None]
    is_cut = valid & (dst_owner != part_ids)

    return PartitionedGraph(
        src_local=jnp.asarray(src_local, jnp.int32),
        dst_global=jnp.asarray(dst_global, jnp.int32),
        dst_owner=jnp.asarray(dst_owner, jnp.int32),
        dst_local=jnp.asarray(dst_local, jnp.int32),
        weight=jnp.asarray(weight, jnp.float32),
        valid=jnp.asarray(valid),
        is_cut=jnp.asarray(is_cut),
        n_vertices=n,
        n_edges=g.n_edges,
        n_parts=P,
        block=int(block),
    )


def inter_edge_counts(pg: PartitionedGraph) -> np.ndarray:
    """Per-partition count of cut (inter-partition) edges — ToKa1's bound."""
    return np.asarray(jnp.sum(jnp.where(pg.valid, pg.is_cut, False), axis=1))
