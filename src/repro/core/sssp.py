"""SP-Async driver (paper Algorithm 2).

Round structure (one outer round = one inter-partition Bellman-Ford step):

  1. *Local phase* — every shard with a non-empty frontier runs its local
     solver to a fixpoint (the paper's intra-node Dijkstra). Idle shards
     take the other branch of a ``lax.cond`` and evaluate a chunk of
     Trishla triangle candidates instead (the paper's "idle processes do
     edge elimination").
  2. *Send phase* — candidate distances over cut edges are pre-aggregated
     per boundary vertex (segment-min) and placed into a statically-routed
     send buffer; only improvements over ``last_sent`` are transmitted.
  3. *Exchange* — one collective: bucketed ``all_to_all`` (default), dense
     ``all_reduce(min)`` (``pmin``), or dense ``all_to_all`` + local min
     (``a2a_dense``).
  4. *Merge phase* — incoming messages scatter-min into the local distance
     block; improved vertices form the next frontier.
  5. *ToKa* — termination detection (see ``core/toka.py``).

Backends:
  - ``sim``: the same phases vmapped over a stacked [P, ...] representation
    on one device, exchanges realized as array transposes/reductions. Used
    for correctness tests at any partition count without real devices.
  - ``shmap``: ``jax.shard_map`` over a mesh; the outer loop is a
    ``lax.while_loop`` *inside* the shard_map body so the whole solve is a
    single compiled program with collectives on the wire. This is the path
    the multi-pod dry-run lowers.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core import toka as toka_mod
from repro.core.local_solver import local_fixpoint
from repro.core.shards import SsspShards
from repro.core import trishla
from repro.distributed.collectives import (
    all_to_all_tiled, and_reduce, flat_rank, or_reduce, ring_permute,
)

INF = jnp.float32(jnp.inf)


@dataclasses.dataclass(frozen=True)
class SsspConfig:
    exchange: str = "bucket"        # bucket | pmin | a2a_dense
    toka: str = "toka0"             # toka0 | toka1 | toka2
    local_solver: str = "bellman"   # bellman | delta | pallas
    delta: float = 4.0
    local_iters: int = 10_000
    pallas_sweeps: int = 8          # relaxation sweeps fused per pallas_call
    pallas_interpret: bool = True   # interpret mode (CPU); False on real TPU
    prune_online: bool = True       # Trishla in the idle branch
    prune_offline_passes: int = 0   # vectorized Trishla before the solve
    tri_chunk: int = 256
    max_rounds: int = 100_000


class SsspStats(NamedTuple):
    rounds: jax.Array
    relaxations: jax.Array   # total edge relaxations (TEPS numerator)
    msgs_sent: jax.Array
    msgs_recv: jax.Array
    pruned_edges: jax.Array


class _Carry(NamedTuple):
    dist: Any
    active: Any
    pruned: Any
    tri_cursor: Any
    last_sent: Any
    msg_count: Any
    toka2: Any
    done: Any
    rounds: Any
    relaxations: Any
    msgs_sent: Any
    msgs_recv: Any


# --------------------------------------------------------------------------
# per-shard phases (no leading P dim; vmapped by sim, direct under shard_map)
# --------------------------------------------------------------------------

def _phase_local(shard: SsspShards, dist, active, pruned, cursor, cfg: SsspConfig):
    """Local solve (frontier non-empty) or Trishla chunk (idle)."""
    e_loc = shard.loc_src.shape[0]
    idle = ~jnp.any(active)

    def solve(dist, pruned, cursor):
        res = local_fixpoint(
            dist, active, shard.loc_src, shard.loc_dst, shard.loc_w,
            pruned[:e_loc], solver=cfg.local_solver,
            max_iters=cfg.local_iters, delta=cfg.delta,
            relax_layout=shard.relax_layout, relax_vb=shard.rx_vb,
            pallas_sweeps=cfg.pallas_sweeps,
            pallas_interpret=cfg.pallas_interpret)
        return res.dist, pruned, cursor, res.relaxations, jnp.int32(0)

    def prune(dist, pruned, cursor):
        if not cfg.prune_online:
            return dist, pruned, cursor, jnp.int32(0), jnp.int32(0)
        w_all = jnp.concatenate([shard.loc_w, shard.cut_w])
        new_pruned, new_cursor, n = trishla.prune_chunk(
            w_all, pruned, cursor, shard.tri_uj, shard.tri_ui, shard.tri_ij,
            shard.tri_valid, cfg.tri_chunk)
        return dist, new_pruned, new_cursor, jnp.int32(0), n

    return lax.cond(idle, prune, solve, dist, pruned, cursor)


def _phase_send(shard: SsspShards, dist, pruned, last_sent, cfg: SsspConfig):
    """Build the outgoing payload. Returns (payload, last_sent', sends)."""
    e_loc = shard.loc_src.shape[0]
    S = shard.slot_owner.shape[0]
    Pn, C = shard.recv_idx.shape[0], shard.recv_idx.shape[1]

    w_cut = jnp.where(pruned[e_loc:], INF, shard.cut_w)
    d_src = jnp.take(dist, shard.cut_src, mode="fill", fill_value=float("inf"))
    cand = d_src + w_cut
    slot_val = jax.ops.segment_min(cand, shard.cut_seg, num_segments=S,
                                   indices_are_sorted=True)
    improved = shard.slot_valid & (slot_val < last_sent)
    send_val = jnp.where(improved, slot_val, INF)
    new_last = jnp.where(improved, slot_val, last_sent)
    sends = jnp.sum(improved).astype(jnp.int32)

    if cfg.exchange == "bucket":
        payload = jnp.full((Pn, C), INF, jnp.float32)
        payload = payload.at[shard.slot_owner, shard.slot_pos].min(send_val)
    else:  # dense candidate vector addressed by (owner, dst_local)
        payload = jnp.full((Pn, dist.shape[0]), INF, jnp.float32)
        payload = payload.at[shard.slot_owner, shard.slot_dstl].min(send_val)
    return payload, new_last, sends


def _phase_merge(shard: SsspShards, dist, incoming, cfg: SsspConfig):
    """Scatter-min incoming messages into the local block."""
    if cfg.exchange == "bucket":
        flat_val = incoming.reshape(-1)
        flat_idx = shard.recv_idx.reshape(-1)   # sentinel = block -> dropped
        new = dist.at[flat_idx].min(flat_val, mode="drop")
        recvs = jnp.sum(jnp.isfinite(flat_val)).astype(jnp.int32)
    else:
        new = jnp.minimum(dist, incoming)
        recvs = jnp.sum(incoming < dist).astype(jnp.int32)
    new_active = new < dist
    return new, new_active, recvs


# --------------------------------------------------------------------------
# communication backends
# --------------------------------------------------------------------------

class ShmapComm:
    """Collectives inside a shard_map body (axis_names = flattened ring)."""

    def __init__(self, axis_names):
        self.axes = tuple(axis_names)

    def rank(self):
        return flat_rank(self.axes)

    def exchange(self, payload, cfg: SsspConfig):
        if cfg.exchange == "bucket":
            return all_to_all_tiled(payload, self.axes)          # [P, C]
        if cfg.exchange == "pmin":
            merged = lax.pmin(payload, self.axes)                # [P, block]
            return lax.dynamic_index_in_dim(merged, self.rank(), 0,
                                            keepdims=False)
        if cfg.exchange == "a2a_dense":
            recv = all_to_all_tiled(payload, self.axes)          # [P, block]
            return jnp.min(recv, axis=0)
        raise ValueError(cfg.exchange)

    def ring(self, tok):
        return ring_permute(tok, self.axes)

    def all_any(self, flag):
        return or_reduce(flag, self.axes)

    def all_all(self, flag):
        return and_reduce(flag, self.axes)

    def total(self, x):
        return lax.psum(x, self.axes)


class SimComm:
    """Same contracts on stacked [P, ...] arrays (single-device simulator)."""

    def __init__(self, n_parts: int):
        self.P = n_parts

    def rank(self):
        return jnp.arange(self.P, dtype=jnp.int32)

    def exchange(self, payload, cfg: SsspConfig):
        # payload: [P_src, P_dst, *] stacked over senders
        if cfg.exchange == "bucket":
            return jnp.swapaxes(payload, 0, 1)                    # [P_dst, P_src, C]
        # dense: [P_src, P_owner, block] -> per-owner min over senders
        return jnp.min(payload, axis=0)                           # [P_owner, block]

    def ring(self, tok):
        return jax.tree_util.tree_map(lambda x: jnp.roll(x, 1, axis=0), tok)

    def all_any(self, flag):
        return jnp.broadcast_to(jnp.any(flag), flag.shape)

    def all_all(self, flag):
        return jnp.broadcast_to(jnp.all(flag), flag.shape)

    def total(self, x):
        return jnp.broadcast_to(jnp.sum(x, axis=0), x.shape)


# --------------------------------------------------------------------------
# round + termination (shared logic, comm-parameterized)
# --------------------------------------------------------------------------

def _toka_done(cfg, comm, carry, new_active, sends, recvs, inter_edges, n_parts,
               rank, vmapped: bool):
    idle = ~_vany(new_active, vmapped)
    quiescent = comm.all_all(idle)
    if cfg.toka == "toka0":
        return quiescent, carry.toka2
    if cfg.toka == "toka1":
        vote = toka_mod.toka1_vote(carry.msg_count + recvs, inter_edges, n_parts)
        return quiescent | comm.all_all(vote), carry.toka2
    if cfg.toka == "toka2":
        # Safra's counter invariant (sum of sent-received returns to 0)
        # only holds for message transports. The dense exchanges (pmin /
        # a2a_dense) are broadcasts — a sent improvement is not 1:1 with a
        # counted receive — so they run the color-only DFG variant
        # (counters zeroed; sound under BSP where nothing is in flight at
        # round boundaries). Found by the §Perf study: with counters, the
        # ring never observes a zero sum and toka2 spins to max_rounds.
        if cfg.exchange == "bucket":
            acct = _vcall(toka_mod.toka2_account, vmapped, carry.toka2,
                          sends, recvs)
        else:
            zero = jnp.zeros_like(sends)
            acct = _vcall(toka_mod.toka2_account, vmapped, carry.toka2,
                          zero, zero)
            # blacken on send still applies (color drives termination)
            color = jnp.where(sends > 0, jnp.int32(1), acct.color)
            acct = acct._replace(color=color)
        st, outgoing = _vcall(partial(toka_mod.toka2_forward, n_parts=n_parts),
                              vmapped, acct, rank, idle)
        incoming = comm.ring(outgoing)
        st = _vcall(toka_mod.toka2_absorb, vmapped, st, incoming)
        return comm.all_all(st.seen_red), st
    raise ValueError(cfg.toka)


def _vany(x, vmapped):
    return jnp.any(x, axis=-1) if not vmapped else jnp.any(x, axis=tuple(range(1, x.ndim)))


def _vcall(fn, vmapped, *args):
    return jax.vmap(fn)(*args) if vmapped else fn(*args)


def _make_round(shard_or_stack: SsspShards, cfg: SsspConfig, comm, vmapped: bool,
                n_parts: int):
    """Returns round(carry) -> carry, shared by both backends.

    ``vmapped=True``: per-shard phases are vmapped over stacked arrays.
    ``vmapped=False``: phases run directly on a single shard's slice
    (inside shard_map)."""
    sh = shard_or_stack

    local_f = partial(_phase_local, cfg=cfg)
    send_f = partial(_phase_send, cfg=cfg)
    merge_f = partial(_phase_merge, cfg=cfg)
    if vmapped:
        local_f = jax.vmap(local_f)
        send_f = jax.vmap(send_f)
        merge_f = jax.vmap(merge_f)

    def rounds_fn(carry: _Carry) -> _Carry:
        dist, pruned, cursor, nrel, nprune = local_f(
            sh, carry.dist, carry.active, carry.pruned, carry.tri_cursor)
        payload, last_sent, sends = send_f(sh, dist, pruned, carry.last_sent)
        incoming = comm.exchange(payload, cfg)
        dist, new_active, recvs = merge_f(sh, dist, incoming)
        done, toka2 = _toka_done(cfg, comm, carry, new_active, sends, recvs,
                                 sh.inter_edges, n_parts, comm.rank(), vmapped)
        return _Carry(
            dist=dist, active=new_active, pruned=pruned, tri_cursor=cursor,
            last_sent=last_sent, msg_count=carry.msg_count + recvs,
            toka2=toka2, done=done, rounds=carry.rounds + 1,
            relaxations=carry.relaxations + nrel.astype(jnp.int32),
            msgs_sent=carry.msgs_sent + sends.astype(jnp.int32),
            msgs_recv=carry.msgs_recv + recvs.astype(jnp.int32))

    return rounds_fn


def _init_carry(sh: SsspShards, source: int, cfg: SsspConfig, rank, vmapped: bool):
    """Stacked init (sim) or per-shard init (shard_map)."""
    block = sh.block
    n_parts = sh.n_parts
    src_owner = source // block
    src_local = source % block

    if vmapped:
        Pn = n_parts
        dist = jnp.full((Pn, block), INF, jnp.float32)
        dist = dist.at[src_owner, src_local].set(0.0)
        active = jnp.zeros((Pn, block), bool).at[src_owner, src_local].set(True)
        e_all = sh.loc_w.shape[1] + sh.cut_w.shape[1]
        pruned = jnp.zeros((Pn, e_all), bool)
        last_sent = jnp.full((Pn, sh.slot_owner.shape[1]), INF, jnp.float32)
        zero = jnp.zeros((Pn,), jnp.int32)
        zero32 = jnp.zeros((Pn,), jnp.int32)
        toka2 = jax.vmap(toka_mod.toka2_init)(jnp.arange(Pn, dtype=jnp.int32))
        done = jnp.zeros((), bool)
    else:
        dist = jnp.full((block,), INF, jnp.float32)
        mine = rank == src_owner
        dist = dist.at[src_local].set(jnp.where(mine, 0.0, INF))
        active = jnp.zeros((block,), bool).at[src_local].set(mine)
        e_all = sh.loc_w.shape[0] + sh.cut_w.shape[0]
        pruned = jnp.zeros((e_all,), bool)
        last_sent = jnp.full((sh.slot_owner.shape[0],), INF, jnp.float32)
        zero = jnp.zeros((), jnp.int32)
        zero32 = jnp.zeros((), jnp.int32)
        toka2 = toka_mod.toka2_init(rank)
        done = jnp.zeros((), bool)

    if cfg.prune_offline_passes > 0:
        off = partial(trishla.prune_offline, n_passes=cfg.prune_offline_passes)
        if vmapped:
            pruned = jax.vmap(off)(sh.loc_w, sh.cut_w, sh.tri_uj, sh.tri_ui,
                                   sh.tri_ij, sh.tri_valid)
        else:
            pruned = off(sh.loc_w, sh.cut_w, sh.tri_uj, sh.tri_ui, sh.tri_ij,
                         sh.tri_valid)

    return _Carry(dist=dist, active=active, pruned=pruned, tri_cursor=zero,
                  last_sent=last_sent, msg_count=zero, toka2=toka2, done=done,
                  rounds=jnp.zeros((), jnp.int32),
                  relaxations=zero32, msgs_sent=zero32, msgs_recv=zero32)


# --------------------------------------------------------------------------
# public entry points
# --------------------------------------------------------------------------

def solve_sim(sh: SsspShards, source: int, cfg: SsspConfig = SsspConfig()):
    """Single-device simulator: python outer loop, jitted round."""
    comm = SimComm(sh.n_parts)
    round_fn = jax.jit(_make_round(sh, cfg, comm, vmapped=True,
                                   n_parts=sh.n_parts))
    carry = _init_carry(sh, source, cfg, rank=None, vmapped=True)
    r = 0
    while r < cfg.max_rounds:
        carry = round_fn(carry)
        r += 1
        if bool(carry.done if carry.done.ndim == 0 else carry.done.all()):
            break
    dist = np.asarray(carry.dist).reshape(-1)[: sh.n_vertices]
    stats = SsspStats(
        rounds=jnp.int32(r),
        relaxations=jnp.sum(carry.relaxations),
        msgs_sent=jnp.sum(carry.msgs_sent),
        msgs_recv=jnp.sum(carry.msgs_recv),
        pruned_edges=jnp.sum(carry.pruned))
    return dist, stats


def build_shmap_solver(sh_spec: SsspShards, cfg: SsspConfig, mesh,
                       axis_names, source: int):
    """Returns a jittable fn(shards_stacked) -> (dist [P, block], stats).

    The outer round loop is a lax.while_loop inside the shard_map body; the
    whole solve compiles to one XLA program (this is what the dry-run
    lowers for the production meshes).
    """
    axes = tuple(axis_names)
    n_parts = sh_spec.n_parts
    comm = ShmapComm(axes)

    def body(sh_local: SsspShards):
        sh1 = jax.tree_util.tree_map(lambda x: x[0], sh_local)  # strip P dim
        # recv_idx arrives as [1, P, C] -> [P, C]; inter_edges scalar
        rank = comm.rank()
        carry = _init_carry(sh1, source, cfg, rank=rank, vmapped=False)
        round_fn = _make_round(sh1, cfg, comm, vmapped=False, n_parts=n_parts)

        def cond(c: _Carry):
            return (~c.done) & (c.rounds < cfg.max_rounds)

        carry = lax.while_loop(cond, round_fn, carry)
        stats = SsspStats(
            rounds=carry.rounds,
            relaxations=comm.total(carry.relaxations),
            msgs_sent=comm.total(carry.msgs_sent),
            msgs_recv=comm.total(carry.msgs_recv),
            pruned_edges=comm.total(jnp.sum(carry.pruned).astype(jnp.int32)))
        return carry.dist[None], stats  # restore leading P dim

    pspec = P(axes)
    rspec = P()
    in_specs = jax.tree_util.tree_map(lambda _: pspec, sh_spec)
    out_specs = (pspec, SsspStats(rspec, rspec, rspec, rspec, rspec))
    return jax.jit(compat.shard_map(body, mesh=mesh, in_specs=(in_specs,),
                                    out_specs=out_specs, check_vma=False))


def solve_shmap(sh: SsspShards, source: int, cfg: SsspConfig, mesh, axis_names):
    solver = build_shmap_solver(sh, cfg, mesh, axis_names, source)
    dist, stats = solver(sh)
    dist = np.asarray(dist).reshape(-1)[: sh.n_vertices]
    return dist, stats
