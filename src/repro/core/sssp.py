"""SP-Async driver (paper Algorithm 2), batched over a query axis.

The paper solves ONE source per run; this driver is a multi-source *query
engine*: every solve takes K sources at once against the same partitioned
graph, so the one-time preprocessing (partitioning, message routing,
Trishla triangle enumeration, the dst-tiled Pallas edge layout) is
amortized across the whole batch. Sources are a TRACED ``[K]`` input —
``_init_carry`` scatters the source bit inside the program — so one
compiled program per K serves arbitrary source sets on both backends.
The public session surface lives in :mod:`repro.core.engine`
(``SsspEngine``); the free functions at the bottom of this module are
deprecated thin wrappers over it.

The round is an explicit *phase pipeline*: every phase (local, send,
exchange, merge, termination) is a stage resolved from the backend
registry in ``core/phases.py``, keyed by ``SsspConfig`` — so backends
compose freely (e.g. ``local_solver="pallas", send_backend="pallas",
merge_backend="xla"``) in both the sim and shmap drivers, and new stages
slot in without touching the loop. The send and merge phases each have an
``xla`` backend (generic ``segment_min`` / ``at[].min``) and a ``pallas``
backend (the slot-tiled ``kernels/send`` pack and msg-tiled
``kernels/merge`` scatter, over layouts precomputed by ``build_shards``).

Round structure (one outer round = one inter-partition Bellman-Ford step):

  1. *Local phase* — every shard with a non-empty frontier (in ANY live
     query) runs its local solver to a fixpoint for all K queries at once
     (the paper's intra-node Dijkstra, batched). Idle shards take the other
     branch of a ``lax.cond`` and evaluate a chunk of Trishla triangle
     candidates instead (the paper's "idle processes do edge elimination";
     pruning is query-invariant, so it is shared by the batch).
  2. *Send phase* — candidate distances over cut edges are pre-aggregated
     per boundary vertex (segment-min, per query) and placed into a
     statically-routed ``[K, P, C]`` send buffer; only improvements over
     ``last_sent`` are transmitted.
  3. *Exchange* — ONE collective moves the whole batch: bucketed
     ``all_to_all`` (default), dense ``all_reduce(min)`` (``pmin``), or
     dense ``all_to_all`` + local min (``a2a_dense``). The K payloads ride
     in the same transfer — batching multiplies payload bytes, not message
     count or latency terms.
  4. *Merge phase* — incoming messages scatter-min into the local distance
     block per query; improved vertices form the next frontier.
  5. *ToKa* — termination detection (see ``core/toka.py``), PER QUERY: a
     converged-query mask keeps finished queries from relaxing or sending
     while stragglers run; the round loop exits only when all K are done.

Backends:
  - ``sim``: the same phases vmapped over a stacked [P, ...] representation
    on one device, exchanges realized as array transposes/reductions. Used
    for correctness tests at any partition count without real devices.
  - ``shmap``: ``jax.shard_map`` over a mesh; the outer loop is a
    ``lax.while_loop`` *inside* the shard_map body so the whole solve is a
    single compiled program with collectives on the wire. This is the path
    the multi-pod dry-run lowers.

Per-shard state layout: ``dist``/``active`` are [K, block], ``last_sent``
is [K, S]; the Trishla ``pruned`` mask and triangle cursor carry no query
axis (edge pruning is a property of the graph, not of the source).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core import faults as faults_mod
from repro.core import phases
from repro.core import warmstart  # noqa: F401  (registers warm_init backends)
from repro.core import toka as toka_mod
from repro.core.local_solver import local_fixpoint_batch
from repro.core.shards import SsspShards
from repro.core import trishla
from repro.distributed.collectives import (
    all_to_all_tiled, and_reduce, flat_rank, flat_size, or_reduce,
    ring_permute, ring_permute_rev,
)
from repro.kernels.merge import merge_scatter_pallas
from repro.kernels.round import fused_round_pallas, fused_round_rescue
from repro.kernels.send import send_pack_pallas, send_payload_bucket

INF = jnp.float32(jnp.inf)


@dataclasses.dataclass(frozen=True)
class SsspConfig:
    exchange: str = "bucket"        # bucket | pmin | a2a_dense
                                    #   | async | async_bucket | async_ppermute
    toka: str = "toka0"             # toka0 | toka1 | toka2 | toka3
    async_lag: int = 1              # rounds a deferred exchange buffers sends
    local_solver: str = "bellman"   # bellman | delta | pallas
    send_backend: str = "xla"       # xla | pallas (cut-edge segment-min pack)
    merge_backend: str = "xla"      # xla | pallas (incoming scatter-min)
    round: str = "staged"           # staged | fused (whole-round megakernel)
    warm_start: str = "none"        # none | landmark (engine-owned seed cache)
    delta: float = 4.0
    local_iters: int = 10_000
    pallas_sweeps: int = 8          # relaxation sweeps fused per pallas_call
    pallas_interpret: bool = True   # interpret mode (CPU); False on real TPU
    prune_online: bool = True       # Trishla in the idle branch
    prune_offline_passes: int = 0   # vectorized Trishla before the solve
    tri_chunk: int = 256
    max_rounds: int = 100_000
    faults: faults_mod.FaultPlan | None = None  # message failure model
    toka3_safety: float = 2.0       # toka3 quiet-streak safety factor

    def __post_init__(self):
        # eager validation against the phase registry: a typo'd backend
        # name fails HERE with the valid options, not deep inside tracing
        phases.validate("exchange", self.exchange)
        phases.validate("toka", self.toka)
        phases.validate("local_solver", self.local_solver)
        phases.validate("send", self.send_backend)
        phases.validate("merge", self.merge_backend)
        phases.validate("round", self.round)
        phases.validate("warm_init", self.warm_start)
        if self.faults is not None and not isinstance(self.faults,
                                                      faults_mod.FaultPlan):
            raise TypeError(f"cfg.faults must be a FaultPlan or None, got "
                            f"{type(self.faults).__name__}")
        if self.toka3_safety <= 0:
            raise ValueError("toka3_safety must be > 0")
        if self.async_lag < 1:
            raise ValueError("async_lag must be >= 1 (1 = double-buffered)")
        if self.async_lag != 1 and self.exchange not in ("async",
                                                         "async_bucket"):
            raise ValueError(
                f"async_lag={self.async_lag} only applies to the buffered "
                f"deferred exchanges ('async'/'async_bucket'); "
                f"exchange={self.exchange!r} ignores it "
                "(async_ppermute's lag is the ring distance)")

    @property
    def fault_plan(self) -> faults_mod.FaultPlan | None:
        """The ACTIVE fault plan (an all-zero plan degenerates to None, so
        the fault-free pipeline carries no fault state or RNG)."""
        if self.faults is not None and self.faults.active:
            return self.faults
        return None


class SsspStats(NamedTuple):
    rounds: jax.Array        # outer rounds until the LAST query converged
    relaxations: jax.Array   # total edge relaxations (TEPS numerator)
    msgs_sent: jax.Array
    msgs_recv: jax.Array
    pruned_edges: jax.Array
    q_rounds: jax.Array = None        # [K] rounds each query was live
    q_relaxations: jax.Array = None   # [K] edge relaxations per query
    q_converged: jax.Array = None     # [K] detector-done mask per query
    stale_merges: jax.Array = None    # improving late (queued/lagged) deliveries
    resends: jax.Array = None         # anti-entropy retransmissions
    n_dispatches: jax.Array = None    # data-plane dispatches (rounds x per-round)
    overlap_rounds: jax.Array = None  # rounds overlapping comm with compute
    bytes_moved: jax.Array = None     # logical payload bytes on the wire


class _Carry(NamedTuple):
    dist: Any         # [K, block] per shard
    active: Any       # [K, block] per shard
    pruned: Any       # [e_all] per shard (query-invariant)
    tri_cursor: Any
    last_sent: Any    # [K, S] per shard
    msg_count: Any    # [K] per shard
    toka2: Any        # Toka2State with [K]-leading fields
    done: Any         # [K] converged-query mask (globally agreed)
    rounds: Any
    q_rounds: Any     # [K]
    relaxations: Any  # [K]
    msgs_sent: Any    # [K]
    msgs_recv: Any    # [K]
    faults: Any       # FaultState per shard, or None (fault-free)
    streak: Any       # [K] consecutive globally-quiet rounds (toka3)
    stale: Any        # [K] improving stale merges from the fault queue
    resent: Any       # [K] anti-entropy retransmissions
    incoming: Any = None   # fused round: delivered-but-unmerged messages
    front_any: Any = None  # fused round: [K] "some frontier bit next round"
    inflight: Any = None   # deferred exchange: tuple of undelivered payloads
    overlap: Any = None    # scalar: rounds with comm/compute overlap
    comm_bytes: Any = None  # scalar: logical payload bytes this shard moved


# --------------------------------------------------------------------------
# per-shard phases (no leading P dim; vmapped by sim, direct under shard_map)
# --------------------------------------------------------------------------

def _phase_local(shard: SsspShards, dist, active, pruned, cursor, cfg: SsspConfig):
    """Batched local solve (any frontier non-empty) or Trishla chunk (idle).

    ``dist``/``active``: [K, block]. The pruned mask and cursor are shared
    across the batch."""
    e_loc = shard.loc_src.shape[0]
    nq = dist.shape[0]
    idle = ~jnp.any(active)

    def solve(dist, pruned, cursor):
        res = local_fixpoint_batch(
            dist, active, shard.loc_src, shard.loc_dst, shard.loc_w,
            pruned[:e_loc], solver=cfg.local_solver,
            max_iters=cfg.local_iters, delta=cfg.delta,
            relax_layout=shard.relax_layout, relax_vb=shard.rx_vb,
            pallas_sweeps=cfg.pallas_sweeps,
            pallas_interpret=cfg.pallas_interpret)
        return res.dist, pruned, cursor, res.relaxations, jnp.int32(0)

    def prune(dist, pruned, cursor):
        nrel0 = jnp.zeros((nq,), jnp.int32)
        if not cfg.prune_online:
            return dist, pruned, cursor, nrel0, jnp.int32(0)
        w_all = jnp.concatenate([shard.loc_w, shard.cut_w])
        new_pruned, new_cursor, n = trishla.prune_chunk(
            w_all, pruned, cursor, shard.tri_uj, shard.tri_ui, shard.tri_ij,
            shard.tri_valid, cfg.tri_chunk)
        return dist, new_pruned, new_cursor, nrel0, n

    return lax.cond(idle, prune, solve, dist, pruned, cursor)


def _scatter_dense(shard: SsspShards, send_val, blk: int):
    """Masked slot values -> dense [K, P, block] candidate rows addressed
    by (owner, dst_local). Shared by both send backends: the dense payload
    is bandwidth-bound assembly, not a reduction — there is nothing for a
    kernel to win (the segment-min upstream of it is the hot part)."""
    Pn = shard.recv_idx.shape[0]
    return jax.vmap(
        lambda v: jnp.full((Pn, blk), INF, jnp.float32)
        .at[shard.slot_owner, shard.slot_dstl].min(v))(send_val)


@phases.register("send", "xla")
def _phase_send_xla(shard: SsspShards, dist, pruned, last_sent, *,
                    dense: bool, cfg: SsspConfig):
    """Generic XLA pack: per-slot ``segment_min`` + improvement masking.

    Returns (payload [K, P, C] (bucket) or [K, P, block] (dense),
    last_sent' [K, S], sends [K])."""
    e_loc = shard.loc_src.shape[0]
    S = shard.slot_owner.shape[0]
    Pn, C = shard.recv_idx.shape[0], shard.recv_idx.shape[1]

    w_cut = jnp.where(pruned[e_loc:], INF, shard.cut_w)            # [e_cut]
    d_src = jnp.take(dist, shard.cut_src, axis=1, mode="fill",
                     fill_value=float("inf"))                      # [K, e_cut]
    cand = d_src + w_cut
    slot_val = jax.vmap(lambda c: jax.ops.segment_min(
        c, shard.cut_seg, num_segments=S, indices_are_sorted=True))(cand)
    improved = shard.slot_valid & (slot_val < last_sent)           # [K, S]
    send_val = jnp.where(improved, slot_val, INF)
    new_last = jnp.where(improved, slot_val, last_sent)
    sends = jnp.sum(improved, axis=-1).astype(jnp.int32)           # [K]

    if dense:
        payload = _scatter_dense(shard, send_val, dist.shape[1])
    else:
        payload = jax.vmap(
            lambda v: jnp.full((Pn, C), INF, jnp.float32)
            .at[shard.slot_owner, shard.slot_pos].min(v))(send_val)
    return payload, new_last, sends


@phases.register("send", "pallas")
def _phase_send_pallas(shard: SsspShards, dist, pruned, last_sent, *,
                       dense: bool, cfg: SsspConfig):
    """Slot-tiled Pallas pack (``kernels/send``): the segment-min, the
    ``last_sent`` improvement masking, and the send counts all run in ONE
    kernel over the ``tx_*`` layout precomputed by ``build_shards``; the
    bucketed payload scatter becomes a static gather (``tx_payload_slot``).
    Bit-identical to the XLA backend (min is exact; same per-edge sums)."""
    e_loc = shard.loc_src.shape[0]
    lay = shard.send_layout
    if len(lay) == 5:                       # ragged: + chunk→tile map
        src_t, w_t, segrel_t, eid_t, ctile = lay
    else:
        src_t, w_t, segrel_t, eid_t = lay
        ctile = None
    pruned_t = jnp.take(pruned[e_loc:].astype(jnp.int32), eid_t,
                        mode="fill", fill_value=0)
    send_val, new_last, sends = send_pack_pallas(
        dist, last_sent, shard.slot_valid, src_t, w_t, segrel_t, pruned_t,
        ctile, sb=shard.tx_sb, eb=shard.tx_eb,
        interpret=cfg.pallas_interpret)
    if dense:
        payload = _scatter_dense(shard, send_val, dist.shape[1])
    else:
        payload = send_payload_bucket(send_val, shard.tx_payload_slot)
    return payload, new_last, sends


def _merge_dense(dist, incoming):
    """Dense incoming is already owner-addressed: elementwise min, no
    scatter exists for a kernel to replace (shared by both backends)."""
    new = jnp.minimum(dist, incoming)
    recvs = jnp.sum(incoming < dist, axis=-1).astype(jnp.int32)
    return new, new < dist, recvs


@phases.register("merge", "xla")
def _phase_merge_xla(shard: SsspShards, dist, incoming, *, dense: bool,
                     cfg: SsspConfig):
    """Generic XLA scatter-min of incoming messages, per query.

    ``incoming``: [K, P, C] (bucket) or [K, block] (dense). Returns
    (new_dist [K, block], new_active [K, block], recvs [K])."""
    if dense:
        return _merge_dense(dist, incoming)
    nq = dist.shape[0]
    flat_val = incoming.reshape(nq, -1)
    flat_idx = shard.recv_idx.reshape(-1)   # sentinel = block -> dropped
    new = jax.vmap(
        lambda d, v: d.at[flat_idx].min(v, mode="drop"))(dist, flat_val)
    recvs = jnp.sum(jnp.isfinite(flat_val), axis=-1).astype(jnp.int32)
    return new, new < dist, recvs


@phases.register("merge", "pallas")
def _phase_merge_pallas(shard: SsspShards, dist, incoming, *, dense: bool,
                        cfg: SsspConfig):
    """Msg-tiled Pallas scatter (``kernels/merge``) over the static ``mx_*``
    routing layout: scatter-min, next-frontier, and receive counts in ONE
    kernel. Receive counting is bit-identical to the XLA backend because a
    payload position outside the layout (``recv_idx`` sentinel) can only
    ever carry +inf — no sender owns a slot for it."""
    if dense:
        return _merge_dense(dist, incoming)
    nq = dist.shape[0]
    lay = shard.merge_layout
    if len(lay) == 4:                       # ragged: + chunk→tile map
        mx_pos, mx_dstrel, mx_valid, ctile = lay
    else:
        mx_pos, mx_dstrel, mx_valid = lay
        ctile = None
    return merge_scatter_pallas(
        dist, incoming.reshape(nq, -1), mx_pos, mx_dstrel, mx_valid, ctile,
        vb=shard.mx_vb, eb=shard.mx_eb, interpret=cfg.pallas_interpret)


# --------------------------------------------------------------------------
# communication backends
# --------------------------------------------------------------------------

class ShmapComm:
    """Collectives inside a shard_map body (axis_names = flattened ring).

    Payloads carry a leading query axis [K, P, ...]; each exchange is still
    ONE collective — the batch is moved by transposing the query axis in,
    not by issuing K transfers."""

    def __init__(self, axis_names):
        self.axes = tuple(axis_names)

    def rank(self):
        return flat_rank(self.axes)

    def exchange_bucket(self, payload):
        recv = all_to_all_tiled(jnp.swapaxes(payload, 0, 1), self.axes)
        return jnp.swapaxes(recv, 0, 1)                          # [K, P, C]

    def exchange_pmin(self, payload):
        merged = lax.pmin(payload, self.axes)                    # [K, P, block]
        return lax.dynamic_index_in_dim(merged, self.rank(), 1,
                                        keepdims=False)          # [K, block]

    def exchange_a2a_dense(self, payload):
        recv = all_to_all_tiled(jnp.swapaxes(payload, 0, 1), self.axes)
        return jnp.min(recv, axis=0)                             # [K, block]

    def ring(self, tok):
        return ring_permute(tok, self.axes)

    def size(self) -> int:
        return flat_size(self.axes)

    def dest_dirs(self):
        """[P] bool routing table of the bidirectional ring transport:
        True = destination column d travels the FORWARD ring from this
        rank (ties at P/2 go forward). Routing the short way bounds every
        message's delivery lag by floor(P/2) hops."""
        Pn = self.size()
        r = self.rank()
        d = jnp.arange(Pn, dtype=jnp.int32)
        return ((d - r) % Pn) <= ((r - d) % Pn)

    def async_hop(self, fwd, bwd):
        """One bidirectional ring hop of the dense transit buffers
        ``[K, P, block]`` (column p = messages destined for rank p):
        advance ``fwd`` one hop forward and ``bwd`` one hop backward,
        deliver (and clear) the own-rank column of each. Each hop is a
        collective-permute whose operand is carried state, available at
        round START — XLA can run it concurrently with the relax kernel,
        which is the whole overlap story of ``exchange='async_ppermute'``.
        """
        fwd = ring_permute(fwd, self.axes)
        bwd = ring_permute_rev(bwd, self.axes)
        r = self.rank()
        inc = jnp.minimum(
            lax.dynamic_index_in_dim(fwd, r, 1, keepdims=False),
            lax.dynamic_index_in_dim(bwd, r, 1, keepdims=False))
        clear = jnp.full_like(inc, INF)
        fwd = lax.dynamic_update_index_in_dim(fwd, clear, r, 1)
        bwd = lax.dynamic_update_index_in_dim(bwd, clear, r, 1)
        return inc, fwd, bwd

    def min_all(self, x):
        return lax.pmin(x, self.axes)

    def all_any(self, flag):
        return or_reduce(flag, self.axes)

    def all_all(self, flag):
        return and_reduce(flag, self.axes)

    def total(self, x):
        return lax.psum(x, self.axes)


class SimComm:
    """Same contracts on stacked [P, ...] arrays (single-device simulator).

    Reductions act over the shard axis (axis 0) only, leaving the query
    axis intact: flags are [P, K], payloads [P_src, K, P_dst, ...]."""

    def __init__(self, n_parts: int):
        self.P = n_parts

    def rank(self):
        return jnp.arange(self.P, dtype=jnp.int32)

    # payload: [P_src, K, P_dst, *] stacked over senders
    def exchange_bucket(self, payload):
        return jnp.swapaxes(payload, 0, 2)            # [P_dst, K, P_src, C]

    def exchange_pmin(self, payload):
        # dense: [P_src, K, P_owner, block] -> per-owner min over senders
        return jnp.swapaxes(jnp.min(payload, axis=0), 0, 1)  # [P_owner, K, block]

    exchange_a2a_dense = exchange_pmin  # same single-device realization

    def ring(self, tok):
        return jax.tree_util.tree_map(lambda x: jnp.roll(x, 1, axis=0), tok)

    def size(self) -> int:
        return self.P

    def dest_dirs(self):
        # stacked [P_src, P_dst] forward-routing mask (see ShmapComm)
        Pn = self.P
        r = self.rank()[:, None]
        d = jnp.arange(Pn, dtype=jnp.int32)[None, :]
        return ((d - r) % Pn) <= ((r - d) % Pn)

    def async_hop(self, fwd, bwd):
        # stacked [P, K, P, block]: the +1/-1 rolls over the shard axis
        # are the single-device realization of the two ring permutes —
        # bit-level oracle of the shmap transport (same hop schedule)
        fwd = jnp.roll(fwd, 1, axis=0)
        bwd = jnp.roll(bwd, -1, axis=0)

        def one(f, b, r):
            inc = jnp.minimum(
                lax.dynamic_index_in_dim(f, r, 1, keepdims=False),
                lax.dynamic_index_in_dim(b, r, 1, keepdims=False))
            clear = jnp.full_like(inc, INF)
            f = lax.dynamic_update_index_in_dim(f, clear, r, 1)
            b = lax.dynamic_update_index_in_dim(b, clear, r, 1)
            return inc, f, b

        return jax.vmap(one)(fwd, bwd, self.rank())

    def all_any(self, flag):
        return jnp.broadcast_to(jnp.any(flag, axis=0), flag.shape)

    def all_all(self, flag):
        return jnp.broadcast_to(jnp.all(flag, axis=0), flag.shape)

    def total(self, x):
        return jnp.broadcast_to(jnp.sum(x, axis=0), x.shape)


# --------------------------------------------------------------------------
# exchange + termination stages (comm-parameterized)
# --------------------------------------------------------------------------

class ExchangeStage(NamedTuple):
    """Registry entry for an exchange mode: ``dense`` selects the payload
    shape the send/merge stages build/consume ([K, P, block] vs the
    bucketed [K, P, C]); ``run(comm, payload)`` realizes the transfer on
    either comm backend.

    ``deferred=True`` marks an ASYNCHRONOUS exchange: the round does not
    call ``run`` — it splits the transfer around the local compute so the
    collective only ever consumes state carried from previous rounds
    (``carry.inflight``), which is ready at round START and therefore
    overlappable with the relax kernel:

    - ``recv(comm, inflight) -> (incoming, inflight_mid)`` issues the
      collective over carried payloads and returns this round's delivered
      batch (round r receives what round r-1-lag sent);
    - ``push(comm, inflight_mid, payload) -> inflight'`` enqueues this
      round's fresh sends into the in-flight buffer (no collective);
    - ``init_inflight(sh, nq, cfg, vmapped)`` builds the empty (+inf)
      buffer pytree; ``flush(comm, inflight) -> [incoming, ...]`` drains
      every undelivered batch at exit time (``make_finalize``).
    """
    name: str
    dense: bool
    run: Any
    deferred: bool = False
    recv: Any = None
    push: Any = None
    init_inflight: Any = None
    flush: Any = None


def _async_bucket_recv(comm, inflight):
    # the all_to_all consumes ONLY carried state -> overlappable; the
    # oldest buffered payload is delivered, the rest keep aging
    return comm.exchange_bucket(inflight[0]), inflight[1:]


def _async_bucket_push(comm, inflight, payload):
    return inflight + (payload,)


def _async_bucket_init(sh, nq: int, cfg, vmapped: bool):
    Pn, C = sh.n_parts, sh.recv_idx.shape[-1]
    shape = (Pn, nq, Pn, C) if vmapped else (nq, Pn, C)
    return tuple(jnp.full(shape, INF, jnp.float32)
                 for _ in range(cfg.async_lag))


def _async_bucket_flush(comm, inflight):
    return [comm.exchange_bucket(b) for b in inflight]


def _async_ppermute_recv(comm, inflight):
    inc, fwd, bwd = comm.async_hop(*inflight)
    return inc, (fwd, bwd)


def _async_ppermute_push(comm, inflight, payload):
    # min-combine fresh sends into the transit buffers: the dense payload
    # is owner/vertex-addressed, so en-route combining is exact (bucketed
    # slot positions are source-relative and could NOT be combined here)
    fwd, bwd = inflight
    go_fwd = comm.dest_dirs()
    mask = (go_fwd[:, None, :, None] if go_fwd.ndim == 2
            else go_fwd[None, :, None])
    fwd = jnp.minimum(fwd, jnp.where(mask, payload, INF))
    bwd = jnp.minimum(bwd, jnp.where(mask, INF, payload))
    return (fwd, bwd)


def _async_ppermute_init(sh, nq: int, cfg, vmapped: bool):
    Pn, blk = sh.n_parts, sh.block
    shape = (Pn, nq, Pn, blk) if vmapped else (nq, Pn, blk)
    z = jnp.full(shape, INF, jnp.float32)
    return (z, z)


def _async_ppermute_flush(comm, inflight):
    # short-way routing bounds any message's remaining ring distance by
    # floor(P/2) hops; min-merge order is irrelevant (monotone merge)
    out = []
    for _ in range(comm.size() // 2):
        inc, inflight = _async_ppermute_recv(comm, inflight)
        out.append(inc)
    return out


phases.register("exchange", "bucket")(ExchangeStage(
    "bucket", dense=False, run=lambda comm, p: comm.exchange_bucket(p)))
phases.register("exchange", "pmin")(ExchangeStage(
    "pmin", dense=True, run=lambda comm, p: comm.exchange_pmin(p)))
phases.register("exchange", "a2a_dense")(ExchangeStage(
    "a2a_dense", dense=True, run=lambda comm, p: comm.exchange_a2a_dense(p)))

# deferred (asynchronous) exchanges: round r's relax runs concurrently
# with delivery of round r-1's sends, merged one round late. "async" is
# the double-buffered bucketed all-to-all (cfg.async_lag buffers; the
# sim realization is the bit-level oracle of the shmap one);
# "async_ppermute" decomposes the dense all-to-all into bidirectional
# ppermute neighbor hops over the partition ring — per-round latency is
# one neighbor hop instead of a full all-to-all barrier, at the price of
# ring-distance delivery lag (extra rounds). The ``run`` members are the
# synchronous realizations, used only by phase-isolation tooling.
_ASYNC_BUCKET = ExchangeStage(
    "async", dense=False, run=lambda comm, p: comm.exchange_bucket(p),
    deferred=True, recv=_async_bucket_recv, push=_async_bucket_push,
    init_inflight=_async_bucket_init, flush=_async_bucket_flush)
phases.register("exchange", "async")(_ASYNC_BUCKET)
phases.register("exchange", "async_bucket")(
    _ASYNC_BUCKET._replace(name="async_bucket"))
phases.register("exchange", "async_ppermute")(ExchangeStage(
    "async_ppermute", dense=True,
    run=lambda comm, p: comm.exchange_a2a_dense(p),
    deferred=True, recv=_async_ppermute_recv, push=_async_ppermute_push,
    init_inflight=_async_ppermute_init, flush=_async_ppermute_flush))

# round pipeline shape: the staged local/send/merge phase chain, or the
# whole-round Pallas megakernel (kernels/round) with one data-plane
# dispatch per round besides the exchange
phases.register("round", "staged")("staged")
phases.register("round", "fused")("fused")


def _round_mode(sh: SsspShards, cfg: SsspConfig) -> str:
    """Resolved round pipeline. ``round='fused'`` needs ALL THREE tiled
    layouts (relax ``rx_*``, send ``tx_*``, merge ``mx_*``); when any is
    missing the fused backend degrades to the staged pipeline with a
    one-time warning, mirroring the per-phase pallas fallbacks."""
    if cfg.round != "fused":
        return "staged"
    if sh.has_relax_layout and sh.has_send_layout and sh.has_merge_layout:
        return "fused"
    phases.warn_once(
        "round.fused.no_layout",
        "round='fused' falling back to the staged pipeline: the shards are "
        "missing the dst-/slot-/msg-tiled layouts (build_shards was called "
        "with relax_layout=False or comm_layout=False)")
    return "staged"


def dispatches_per_round(sh: SsspShards, cfg: SsspConfig) -> int:
    """Data-plane dispatches per round: the staged pipeline launches 4
    (local solve, send pack, exchange collective, merge scatter); the
    fused round launches 2 (megakernel + exchange collective)."""
    return 2 if _round_mode(sh, cfg) == "fused" else 4


def _vcall(fn, vmapped, *args, in_axes=0):
    """vmap ``fn`` over the query axis (always) and the shard axis (sim)."""
    f = jax.vmap(fn, in_axes=in_axes)
    if vmapped:
        f = jax.vmap(f)
    return f(*args)


def _quiescent(comm, new_active):
    """Globally-agreed [K] mask: no shard has a live frontier for query k."""
    idle = ~jnp.any(new_active, axis=-1)            # [K] (or [P, K] in sim)
    return comm.all_all(idle), idle


def _pending_inflight(inflight, vmapped: bool):
    """Per-query "this shard still holds undelivered async payload" bits
    ([K], or [P, K] stacked) — the deferred-exchange analogue of the fault
    queue's ``pending``: ORed into the termination view so no detector can
    declare quiescence over in-flight messages."""
    lead = 2 if vmapped else 1
    bits = None
    for a in jax.tree_util.tree_leaves(inflight):
        b = jnp.any(jnp.isfinite(a), axis=tuple(range(lead, a.ndim)))
        bits = b if bits is None else (bits | b)
    return bits


def _mask_payload(payload):
    """Mask unused per-(query, destination) payload columns to +inf and
    price this round's transfer. A column is used iff the send pack routed
    at least one ``last_sent`` improvement into it, so finiteness over the
    trailing slot/vertex axis IS the improvement-count mask; the masking
    enforces (rather than assumes) that unimproved columns ship no values,
    and the byte count is the honest wire cost the dense payloads hide at
    high P: 4 B x column width x used columns, summed over queries and
    destination ranks (and, in the stacked sim, over sender shards)."""
    used = jnp.any(jnp.isfinite(payload), axis=-1)
    nbytes = (jnp.int32(4 * payload.shape[-1])
              * jnp.sum(used).astype(jnp.int32))
    return jnp.where(used[..., None], payload, INF), nbytes


def _count_improving(shard: SsspShards, dist, incoming, dense: bool):
    """[K] improving deliveries of a batch vs the pre-merge distances.

    Under a deferred exchange EVERY delivered batch is at least one round
    old, so its improving merges are by definition stale merges — this is
    the per-round ``stale_merges`` accounting for the async modes (the
    fault injector's own stale counter is skipped there: queue releases
    are already min-merged into the delivered batch, and counting the
    final batch once avoids double counting)."""
    if dense:
        return jnp.sum(incoming < dist, axis=-1).astype(jnp.int32)
    nq = dist.shape[0]
    flat = incoming.reshape(nq, -1)
    d_t = jnp.take(dist, shard.recv_idx.reshape(-1), axis=1, mode="fill",
                   fill_value=-float("inf"))
    return jnp.sum(flat < d_t, axis=-1).astype(jnp.int32)


# Per-query termination stages: every detector runs K independent instances
# (toka2 circulates K tokens in the same ring hop). Uniform signature
# returning ([K] done mask, toka2', streak'). ``new_active`` here is the
# TERMINATION view of the frontier: under fault injection the round ORs in
# per-query ``pending`` bits (messages still in the delay queue, or drops
# awaiting an anti-entropy resend), so no detector can declare quiescence
# over in-flight state — the real frontier in the carry stays untouched.

@phases.register("toka", "toka0")
def _toka0_stage(cfg, comm, carry, new_active, sends, recvs, inter_edges,
                 n_parts, rank, vmapped: bool):
    quiescent, _ = _quiescent(comm, new_active)
    return quiescent, carry.toka2, carry.streak


@phases.register("toka", "toka1")
def _toka1_stage(cfg, comm, carry, new_active, sends, recvs, inter_edges,
                 n_parts, rank, vmapped: bool):
    quiescent, _ = _quiescent(comm, new_active)
    ie = inter_edges[:, None] if vmapped else inter_edges
    vote = toka_mod.toka1_vote(carry.msg_count + recvs, ie, n_parts)
    return quiescent | comm.all_all(vote), carry.toka2, carry.streak


@phases.register("toka", "toka2")
def _toka2_stage(cfg, comm, carry, new_active, sends, recvs, inter_edges,
                 n_parts, rank, vmapped: bool):
    # Safra's counter invariant (sum of sent-received returns to 0)
    # only holds for message transports. The dense exchanges (pmin /
    # a2a_dense) are broadcasts — a sent improvement is not 1:1 with a
    # counted receive — so they run the color-only DFG variant
    # (counters zeroed; sound under BSP where nothing is in flight at
    # round boundaries). Found by the §Perf study: with counters, the
    # ring never observes a zero sum and toka2 spins to max_rounds.
    # Fault injection breaks the invariant the same way (a dropped send
    # is never received; a released duplicate is an unmatched receive),
    # so an active FaultPlan also forces the color-only variant — the
    # pending-aware idle bit already holds the ring open for in-flight
    # messages.
    _, idle = _quiescent(comm, new_active)
    counters_ok = (not phases.resolve("exchange", cfg.exchange).dense
                   and cfg.fault_plan is None)
    if counters_ok:
        acct = _vcall(toka_mod.toka2_account, vmapped, carry.toka2,
                      sends, recvs)
    else:
        zero = jnp.zeros_like(sends)
        acct = _vcall(toka_mod.toka2_account, vmapped, carry.toka2,
                      zero, zero)
        # blacken on send still applies (color drives termination)
        color = jnp.where(sends > 0, jnp.int32(1), acct.color)
        acct = acct._replace(color=color)
    st, outgoing = _vcall(partial(toka_mod.toka2_forward, n_parts=n_parts),
                          vmapped, acct, rank, idle, in_axes=(0, None, 0))
    incoming = comm.ring(outgoing)
    st = _vcall(toka_mod.toka2_absorb, vmapped, st, incoming)
    return comm.all_all(st.seen_red), st, carry.streak


@phases.register("toka", "toka3")
def _toka3_stage(cfg, comm, carry, new_active, sends, recvs, inter_edges,
                 n_parts, rank, vmapped: bool):
    # The paper's timeout heuristic: count consecutive rounds with NO
    # global activity for a query (no frontier, no sends, no receives,
    # nothing pending in a fault queue) and stop once the streak reaches
    # the bound computed from inter-edge and partition counts
    # (toka.toka3_bound; fault plans widen it by their slack). Activity is
    # agreed by one all-reduce, so every shard advances the same streak
    # and the vote needs no second collective.
    slack = 0 if cfg.fault_plan is None else cfg.fault_plan.fault_slack
    ex_st = phases.resolve("exchange", cfg.exchange)
    if getattr(ex_st, "deferred", False):
        # a deferred exchange keeps messages legitimately in flight across
        # round boundaries: widen the timeout by the worst-case delivery
        # lag (the buffered rounds, plus the short-way ring radius for the
        # dense hop transport). The pending bits already hold the streak
        # at zero while payload is in flight; the slack covers the gap
        # between a send and its first visibility as pending activity.
        slack += cfg.async_lag + (n_parts // 2 if ex_st.dense else 0)
    # the bound must be computed from the GLOBAL cut count: a per-shard
    # bound lets devices disagree on the timeout, which under shard_map
    # means different while-loop trip counts — a collective rendezvous
    # deadlock. comm.total() also matches the host-side toka3_timeout
    # tool, which has always taken the total inter-edge count.
    ie_total = comm.total(jnp.asarray(inter_edges).astype(jnp.int32))
    bound = toka_mod.toka3_bound(ie_total, n_parts, cfg.toka3_safety,
                                 slack)
    act = jnp.any(new_active, axis=-1) | (sends > 0) | (recvs > 0)
    busy = comm.all_any(act)
    streak = jnp.where(busy, 0, carry.streak + 1)
    if vmapped:
        bound = bound[:, None]          # [P] totals -> broadcast [P, K]
    return streak >= bound, carry.toka2, streak


# --------------------------------------------------------------------------
# pipeline resolution + round
# --------------------------------------------------------------------------

class RoundPipeline(NamedTuple):
    """The round's stages, resolved once per (shards, config) from the
    backend registry. ``local``/``send``/``merge`` are per-shard callables
    (vmapped by the sim backend, direct under shard_map); ``exchange`` is
    an :class:`ExchangeStage`; ``toka`` is the termination stage."""
    local: Any
    send: Any
    exchange: ExchangeStage
    merge: Any
    toka: Any


def build_pipeline(sh: SsspShards, cfg: SsspConfig) -> RoundPipeline:
    """Resolve every phase backend for these shards.

    Pallas send/merge backends need the ``tx_*``/``mx_*`` layouts from
    ``build_shards``; when absent (``comm_layout=False``) they degrade to
    the XLA backends with a one-time warning, mirroring the pallas local
    solver's ``relax_layout`` rule. An active ``cfg.faults`` plan wraps
    the resolved exchange stage with the fault-injecting decorator
    (:func:`repro.core.faults.wrap_exchange`) — the transfer itself is
    untouched; delivery goes through the injector."""
    ex = phases.resolve("exchange", cfg.exchange)
    if cfg.fault_plan is not None:
        ex = faults_mod.wrap_exchange(ex, cfg.fault_plan)
    send_backend = cfg.send_backend
    if send_backend == "pallas" and not sh.has_send_layout:
        phases.warn_once(
            "send.pallas.no_layout",
            "send_backend='pallas' falling back to 'xla': the shards carry "
            "no slot-tiled cut-edge layout (build_shards was called with "
            "comm_layout=False)")
        send_backend = "xla"
    merge_backend = cfg.merge_backend
    if merge_backend == "pallas" and not sh.has_merge_layout:
        phases.warn_once(
            "merge.pallas.no_layout",
            "merge_backend='pallas' falling back to 'xla': the shards carry "
            "no msg-tiled receive layout (build_shards was called with "
            "comm_layout=False)")
        merge_backend = "xla"
    return RoundPipeline(
        local=partial(_phase_local, cfg=cfg),
        send=partial(phases.resolve("send", send_backend),
                     dense=ex.dense, cfg=cfg),
        exchange=ex,
        merge=partial(phases.resolve("merge", merge_backend),
                      dense=ex.dense, cfg=cfg),
        toka=phases.resolve("toka", cfg.toka))


def _phase_fused(shard: SsspShards, dist, front_in, live, incoming, last_sent,
                 pruned, *, dense: bool, cfg: SsspConfig):
    """One megakernel dispatch: merge + local fixpoint + send pack
    (``kernels/round``), plus the payload assembly.

    Returns (new_dist, payload, last_sent', sends, nrel, resid) — a
    non-empty ``resid`` row means ``cfg.pallas_sweeps`` in-kernel sweeps
    did not reach the local fixpoint and the caller must rescue the round
    with :func:`_phase_fused_rescue` before using the send outputs."""
    e_loc = shard.loc_src.shape[0]
    nq = dist.shape[0]
    inc = incoming if dense else incoming.reshape(nq, -1)
    new_dist, send_val, new_last, nrel, sends, resid = fused_round_pallas(
        dist, front_in, live, inc, last_sent, shard.slot_valid,
        shard.relax_layout, shard.send_layout, shard.merge_layout,
        pruned[:e_loc], pruned[e_loc:], vb=shard.rx_vb, sb=shard.tx_sb,
        n_sweeps=cfg.pallas_sweeps, dense=dense,
        interpret=cfg.pallas_interpret)
    if dense:
        payload = _scatter_dense(shard, send_val, dist.shape[1])
    else:
        payload = send_payload_bucket(send_val, shard.tx_payload_slot)
    return new_dist, payload, new_last, sends, nrel, resid


def _phase_fused_rescue(shard: SsspShards, dist, resid, last_sent, pruned, *,
                        dense: bool, cfg: SsspConfig):
    """Finish a fused round whose in-kernel sweeps left a residual
    frontier: continue the fixpoint with the batched relax kernel and
    re-pack the sends against the ORIGINAL ``last_sent`` (the megakernel's
    send outputs were computed from unconverged distances). Returns
    (new_dist, payload, last_sent', sends, nrel_extra)."""
    e_loc = shard.loc_src.shape[0]
    new_dist, send_val, new_last, nrel_extra, sends = fused_round_rescue(
        dist, resid, last_sent, shard.slot_valid, shard.relax_layout,
        shard.send_layout, pruned[:e_loc], pruned[e_loc:], vb=shard.rx_vb,
        sb=shard.tx_sb, n_sweeps=cfg.pallas_sweeps,
        max_iters=cfg.local_iters, interpret=cfg.pallas_interpret)
    if dense:
        payload = _scatter_dense(shard, send_val, dist.shape[1])
    else:
        payload = send_payload_bucket(send_val, shard.tx_payload_slot)
    return new_dist, payload, new_last, sends, nrel_extra


def make_finalize(sh: SsspShards, cfg: SsspConfig, comm, vmapped: bool):
    """Exit-time ``fn(carry) -> dist`` merging every delivered-but-unmerged
    and in-flight message batch, or None when nothing can be outstanding
    (staged round + synchronous exchange).

    The fused round rotates the phase chain — a round merges the PREVIOUS
    round's delivered messages — so the loop can exit with one batch of
    delivered-but-unmerged messages in ``carry.incoming``. A deferred
    (async) exchange can additionally exit with undelivered payload in
    ``carry.inflight`` (e.g. a ``max_rounds`` or toka1-budget exit while
    messages ride the pipe): its ``flush`` drains every buffered batch
    here. In both cases accounting already happened (or the detectors held
    termination open via the pending bits); only the value merges are
    outstanding, and min-merge order is irrelevant. The merges run
    unconditionally: correctness of the final distances must not depend on
    the detector's reasoning."""
    ex = phases.resolve("exchange", cfg.exchange)
    deferred = bool(getattr(ex, "deferred", False))
    fused = _round_mode(sh, cfg) == "fused"
    if not fused and not deferred:
        return None
    dense = ex.dense

    def fin(shard, dist, incoming):
        if dense:
            return jnp.minimum(dist, incoming)
        nq = dist.shape[0]
        flat_val = incoming.reshape(nq, -1)
        flat_idx = shard.recv_idx.reshape(-1)
        return jax.vmap(
            lambda d, v: d.at[flat_idx].min(v, mode="drop"))(dist, flat_val)

    if vmapped:
        merge = lambda dist, incoming: jax.vmap(fin)(sh, dist, incoming)
    else:
        merge = lambda dist, incoming: fin(sh, dist, incoming)

    def finalize(carry: _Carry):
        dist = carry.dist
        if fused:
            dist = merge(dist, carry.incoming)
        if deferred:
            for inc in ex.flush(comm, carry.inflight):
                dist = merge(dist, inc)
        return dist

    return finalize


def _make_round_fused(sh: SsspShards, cfg: SsspConfig, comm, vmapped: bool,
                      n_parts: int):
    """The fused-round variant of :func:`_make_round`.

    The phase chain is ROTATED relative to the staged round so the three
    dst-tiled phases land in one dispatch: round r merges the messages
    DELIVERED in round r-1 (held un-merged in ``carry.incoming``), chases
    the resulting frontier to the local fixpoint, packs the sends, and
    exchanges — all activity accounting (receives, frontier-any bits, the
    termination view) happens at delivery time from ``new_dist`` and the
    raw payload, so every per-round statistic and every detector sees
    exactly the sequence the staged pipeline produces (bit-identity is
    enforced by tests/test_fused_round.py). The idle branch (Trishla
    pruning) runs BEFORE the kernel as its own ``lax.cond`` — merge and
    send must still run on idle rounds, so only the prune work is gated."""
    ex = phases.resolve("exchange", cfg.exchange)
    fp = cfg.fault_plan
    if fp is not None:
        ex = faults_mod.wrap_exchange(ex, fp)
    dense = ex.dense
    deferred = bool(getattr(ex, "deferred", False))
    toka_f = phases.resolve("toka", cfg.toka)
    fused_f = partial(_phase_fused, dense=dense, cfg=cfg)
    rescue_f = partial(_phase_fused_rescue, dense=dense, cfg=cfg)

    def prune_f(shard, idle, pruned, cursor):
        if not cfg.prune_online:
            return pruned, cursor

        def prune(p, c):
            w_all = jnp.concatenate([shard.loc_w, shard.cut_w])
            new_p, new_c, _n = trishla.prune_chunk(
                w_all, p, c, shard.tri_uj, shard.tri_ui, shard.tri_ij,
                shard.tri_valid, cfg.tri_chunk)
            return new_p, new_c

        return lax.cond(idle, prune, lambda p, c: (p, c), pruned, cursor)

    def account_f(shard, dist, incoming):
        """Receive counts + per-query any-improvement bits of a delivered
        batch against the post-relax distances — the staged merge phase's
        accounting, computed WITHOUT merging (the values merge next
        round). Bucket: a message improves iff it beats the distance at
        its routed target (sentinel rows gather -inf, never true). Also
        returns the improving-delivery count ``n_imp`` — the deferred
        exchanges' stale-merge tally (see :func:`_count_improving`)."""
        if dense:
            n_imp = jnp.sum(incoming < dist, axis=-1).astype(jnp.int32)
            recvs = n_imp
            any_imp = n_imp > 0
        else:
            nq = dist.shape[0]
            flat = incoming.reshape(nq, -1)
            idx = shard.recv_idx.reshape(-1)
            recvs = jnp.sum(jnp.isfinite(flat), axis=-1).astype(jnp.int32)
            d_t = jnp.take(dist, idx, axis=1, mode="fill",
                           fill_value=-float("inf"))
            n_imp = jnp.sum(flat < d_t, axis=-1).astype(jnp.int32)
            any_imp = n_imp > 0
        return any_imp, recvs, n_imp

    deliver_f = getattr(ex, "deliver", None)
    prune_v, fused_v, rescue_v, account_v = (prune_f, fused_f, rescue_f,
                                             account_f)
    if vmapped:
        prune_v = jax.vmap(prune_f)
        fused_v = jax.vmap(fused_f)
        rescue_v = jax.vmap(rescue_f)
        account_v = jax.vmap(account_f)
        if deliver_f is not None:
            deliver_f = jax.vmap(deliver_f)

    def rounds_fn(carry: _Carry) -> _Carry:
        live = ~carry.done                             # [K] ([P, K] sim)
        idle = ~jnp.any(carry.front_any & live, axis=-1)

        # deferred exchange: issue the collective FIRST — it consumes only
        # carried state, so XLA is free to overlap it with the megakernel.
        # With async the total merge lag is 2 (one round of incoming
        # rotation + one round in flight); correctness is lag-independent
        # (monotone min merge), only round counts move.
        incoming_new = inflight_mid = delivering = None
        if deferred:
            pend0 = _pending_inflight(carry.inflight, vmapped)
            delivering = jnp.any(pend0, axis=-1)    # per-shard bool
            incoming_new, inflight_mid = ex.recv(comm, carry.inflight)

        pruned, cursor = prune_v(sh, idle, carry.pruned, carry.tri_cursor)
        # injected frontier (warm-start seeds / source bits on round 0;
        # zeroed by every fused round thereafter)
        front_in = carry.active & live[..., None]

        # anti-entropy resend window (same latch protocol as the staged
        # round; see _make_round)
        resend_now = None
        last_in = carry.last_sent
        if fp is not None and fp.resend_period > 0:
            period = jnp.int32(fp.resend_period)
            period_hit = (carry.rounds % period) == (period - 1)
            need = comm.all_any(carry.faults.unhealed)
            resend_now = period_hit & need
            last_in = jnp.where(resend_now[..., None], INF, carry.last_sent)

        dist, payload, last_sent, sends, nrel, resid = fused_v(
            sh, carry.dist, front_in, live, carry.incoming, last_in, pruned)

        # rescue: predicate reduced over the WHOLE shard stack, so the sim
        # backend branches for real (an unbatched lax.cond) and the common
        # all-converged round never pays for the continuation
        def rescue(args):
            d, pl_, ls, sd, nr, rs, li, pr = args
            d2, pl2, ls2, sd2, extra = rescue_v(sh, d, rs, li, pr)
            return d2, pl2, ls2, sd2, nr + extra

        def keep(args):
            d, pl_, ls, sd, nr, _rs, _li, _pr = args
            return d, pl_, ls, sd, nr

        dist, payload, last_sent, sends, nrel = lax.cond(
            jnp.any(resid > 0), rescue, keep,
            (dist, payload, last_sent, sends, nrel, resid, last_in, pruned))

        payload, nbytes = _mask_payload(payload)
        if deferred:
            inflight = ex.push(comm, inflight_mid, payload)
        else:
            incoming_new = ex.run(comm, payload)
            inflight = carry.inflight

        fstate, stale, pending = carry.faults, None, None
        if deliver_f is not None:
            if resend_now is not None:
                fstate = fstate._replace(
                    unhealed=jnp.where(resend_now, False, fstate.unhealed))
            rkey = jax.random.fold_in(jax.random.PRNGKey(fp.seed),
                                      carry.rounds)
            rank = comm.rank()
            if vmapped:
                keys = jax.vmap(lambda r: jax.random.fold_in(rkey, r))(rank)
            else:
                keys = jax.random.fold_in(rkey, rank)
            incoming_new, fstate, stale, pending = deliver_f(
                sh, dist, incoming_new, fstate, keys)

        any_imp, recvs, n_imp = account_v(sh, dist, incoming_new)

        # the detectors only consume any(new_active, -1), so a synthetic
        # [.., K, 1] mask carrying the any-improvement bit is equivalent
        # to the staged merge's full frontier plane
        toka_flag = any_imp
        if pending is not None:
            toka_flag = toka_flag | pending
        if deferred:
            toka_flag = toka_flag | _pending_inflight(inflight, vmapped)
        done, toka2, streak = toka_f(
            cfg, comm, carry, toka_flag[..., None], sends, recvs,
            sh.inter_edges, n_parts, comm.rank(), vmapped)

        stale_c, resent_c = carry.stale, carry.resent
        if deferred:
            # every delivered batch is >= 1 round old: its improving
            # merges ARE the stale merges (queue releases were already
            # min-merged into it, so the injector's counter is skipped)
            stale_c = stale_c + n_imp
        elif stale is not None:
            stale_c = stale_c + stale
        if resend_now is not None:
            resent_c = resent_c + jnp.where(resend_now, sends,
                                            0).astype(jnp.int32)
        overlap_c = carry.overlap
        if deferred:
            flag = delivering & ~idle
            bit = jnp.any(flag) if vmapped else comm.all_any(flag)
            overlap_c = overlap_c + bit.astype(jnp.int32)
        running = (~carry.done).astype(jnp.int32)
        return _Carry(
            dist=dist, active=jnp.zeros_like(carry.active), pruned=pruned,
            tri_cursor=cursor, last_sent=last_sent,
            msg_count=carry.msg_count + recvs, toka2=toka2,
            done=carry.done | done, rounds=carry.rounds + 1,
            q_rounds=carry.q_rounds + running,
            relaxations=carry.relaxations + nrel.astype(jnp.int32),
            msgs_sent=carry.msgs_sent + sends.astype(jnp.int32),
            msgs_recv=carry.msgs_recv + recvs.astype(jnp.int32),
            faults=fstate, streak=streak, stale=stale_c, resent=resent_c,
            incoming=incoming_new, front_any=any_imp, inflight=inflight,
            overlap=overlap_c, comm_bytes=carry.comm_bytes + nbytes)

    return rounds_fn


def _make_round(shard_or_stack: SsspShards, cfg: SsspConfig, comm, vmapped: bool,
                n_parts: int):
    """Returns round(carry) -> carry, shared by both backends.

    ``vmapped=True``: per-shard phases are vmapped over stacked arrays.
    ``vmapped=False``: phases run directly on a single shard's slice
    (inside shard_map)."""
    sh = shard_or_stack
    if _round_mode(sh, cfg) == "fused":
        return _make_round_fused(sh, cfg, comm, vmapped, n_parts)
    pipe = build_pipeline(sh, cfg)
    fp = cfg.fault_plan
    ex = pipe.exchange
    deferred = bool(getattr(ex, "deferred", False))

    local_f, send_f, merge_f = pipe.local, pipe.send, pipe.merge
    deliver_f = getattr(pipe.exchange, "deliver", None)
    stale_f = partial(_count_improving, dense=ex.dense)
    if vmapped:
        local_f = jax.vmap(local_f)
        send_f = jax.vmap(send_f)
        merge_f = jax.vmap(merge_f)
        stale_f = jax.vmap(stale_f)
        if deliver_f is not None:
            deliver_f = jax.vmap(deliver_f)

    def rounds_fn(carry: _Carry) -> _Carry:
        # deferred exchange: the collective is issued FIRST and consumes
        # only carried state (round r delivers round r-1-lag's sends), so
        # XLA is free to overlap it with the local relax below — the
        # paper's asynchronous mode: no per-round barrier between a
        # shard's compute and the delivery of its neighbors' messages
        incoming = inflight_mid = delivering = None
        if deferred:
            pend0 = _pending_inflight(carry.inflight, vmapped)
            delivering = jnp.any(pend0, axis=-1)    # per-shard bool
            incoming, inflight_mid = ex.recv(comm, carry.inflight)

        # converged-query mask: finished queries stop relaxing and sending
        # while stragglers run (their frontier is forced empty)
        act = carry.active & ~carry.done[..., None]
        dist, pruned, cursor, nrel, nprune = local_f(
            sh, carry.dist, act, carry.pruned, carry.tri_cursor)

        # anti-entropy: every resend_period-th round, senders forget their
        # last_sent floor for any query some receiver reported an unhealed
        # mattering drop on (one all-reduce of the latches), so the send
        # phase retransmits EVERY current slot minimum for it — slot
        # values are monotone non-increasing, so the recomputed floor is
        # correct and the dropped message is healed by this round's copy
        # (unless dropped again; the receiver's latch re-arms and keeps
        # termination open). Gating on the latch — rather than resending
        # unconditionally — is what lets the system ever look quiet: a
        # periodic blind burst would blacken toka2's ring and reset
        # toka3's streak forever.
        resend_now = None
        last_in = carry.last_sent
        if fp is not None and fp.resend_period > 0:
            period = jnp.int32(fp.resend_period)
            period_hit = (carry.rounds % period) == (period - 1)
            need = comm.all_any(carry.faults.unhealed)   # [K] ([P, K] sim)
            resend_now = period_hit & need
            last_in = jnp.where(resend_now[..., None], INF, carry.last_sent)

        payload, last_sent, sends = send_f(sh, dist, pruned, last_in)
        payload, nbytes = _mask_payload(payload)
        if deferred:
            inflight = ex.push(comm, inflight_mid, payload)
        else:
            incoming = ex.run(comm, payload)
            inflight = carry.inflight

        fstate, stale, pending = carry.faults, None, None
        if deliver_f is not None:
            if resend_now is not None:
                # this resend round retransmits everything: clear the
                # unhealed latch BEFORE injection so only drops of the
                # resent copies themselves re-arm it
                fstate = fstate._replace(
                    unhealed=jnp.where(resend_now, False, fstate.unhealed))
            rkey = jax.random.fold_in(jax.random.PRNGKey(fp.seed),
                                      carry.rounds)
            rank = comm.rank()
            if vmapped:
                keys = jax.vmap(lambda r: jax.random.fold_in(rkey, r))(rank)
            else:
                keys = jax.random.fold_in(rkey, rank)
            incoming, fstate, stale, pending = deliver_f(
                sh, dist, incoming, fstate, keys)

        stale_async = None
        if deferred:
            # improving entries of the FINAL delivered batch (post fault
            # injection) against the pre-merge distances: under a lagged
            # delivery every improving merge is by definition stale
            stale_async = stale_f(sh, dist, incoming)

        dist, new_active, recvs = merge_f(sh, dist, incoming)

        # termination sees pending in-flight state as activity; the real
        # frontier stays clean (a fake frontier bit would cause spurious
        # relaxation work, not just a held-open detector)
        pend_bits = pending
        if deferred:
            ab = _pending_inflight(inflight, vmapped)
            pend_bits = ab if pend_bits is None else (pend_bits | ab)
        toka_active = new_active
        if pend_bits is not None:
            toka_active = new_active | pend_bits[..., None]
        done, toka2, streak = pipe.toka(
            cfg, comm, carry, toka_active, sends, recvs, sh.inter_edges,
            n_parts, comm.rank(), vmapped)

        stale_c, resent_c = carry.stale, carry.resent
        if stale_async is not None:
            # the injector's own stale counter is skipped: queue releases
            # are already min-merged into the delivered batch above
            stale_c = stale_c + stale_async
        elif stale is not None:
            stale_c = stale_c + stale
        if resend_now is not None:
            resent_c = resent_c + jnp.where(resend_now, sends,
                                            0).astype(jnp.int32)
        overlap_c = carry.overlap
        if deferred:
            # a round overlaps when some shard had payload on the wire
            # while some shard had a live frontier to relax
            computing = jnp.any(act, axis=(-2, -1))
            flag = delivering & computing
            bit = jnp.any(flag) if vmapped else comm.all_any(flag)
            overlap_c = overlap_c + bit.astype(jnp.int32)
        running = (~carry.done).astype(jnp.int32)
        return _Carry(
            dist=dist, active=new_active, pruned=pruned, tri_cursor=cursor,
            last_sent=last_sent, msg_count=carry.msg_count + recvs,
            toka2=toka2, done=carry.done | done, rounds=carry.rounds + 1,
            q_rounds=carry.q_rounds + running,
            relaxations=carry.relaxations + nrel.astype(jnp.int32),
            msgs_sent=carry.msgs_sent + sends.astype(jnp.int32),
            msgs_recv=carry.msgs_recv + recvs.astype(jnp.int32),
            faults=fstate, streak=streak, stale=stale_c, resent=resent_c,
            inflight=inflight, overlap=overlap_c,
            comm_bytes=carry.comm_bytes + nbytes)

    return rounds_fn


def sim_phase_fns(sh: SsspShards, cfg: SsspConfig):
    """Jitted per-phase callables over the stacked sim representation —
    the per-phase attribution hook for benchmarks: each phase of the round
    (local / send / exchange / merge) can be driven and timed in isolation
    on real mid-solve state. Shapes follow the sim carry convention
    (leading [P], then [K])."""
    comm = SimComm(sh.n_parts)
    pipe = build_pipeline(sh, cfg)
    fns = {
        "local": jax.jit(lambda dist, active, pruned, cursor:
                         jax.vmap(pipe.local)(sh, dist, active, pruned,
                                              cursor)),
        "send": jax.jit(lambda dist, pruned, last_sent:
                        jax.vmap(pipe.send)(sh, dist, pruned, last_sent)),
        "exchange": jax.jit(lambda payload: pipe.exchange.run(comm, payload)),
        "merge": jax.jit(lambda dist, incoming:
                         jax.vmap(pipe.merge)(sh, dist, incoming)),
    }
    if sh.has_relax_layout and sh.has_send_layout and sh.has_merge_layout:
        fused = partial(_phase_fused, dense=pipe.exchange.dense, cfg=cfg)
        fns["fused"] = jax.jit(
            lambda dist, front_in, live, incoming, last_sent, pruned:
            jax.vmap(fused)(sh, dist, front_in, live, incoming, last_sent,
                            pruned))
    return fns


def _toka2_init_batch(rank, nq: int):
    """K independent token-ring states (shard 0 holds all K tokens)."""
    st = toka_mod.toka2_init(rank)
    return jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x, (nq,) + jnp.shape(x)), st)


def _init_carry(sh: SsspShards, sources, cfg: SsspConfig, rank,
                vmapped: bool, q_valid=None, seed_dist=None):
    """Stacked init (sim) or per-shard init (shard_map) for K sources.

    ``sources`` is a TRACED [K] int32 array (a python sequence is accepted
    and converted): the source bit is scattered, not baked, so one compiled
    program serves any source batch of a given K. ``q_valid`` masks padded
    bucket rows — an invalid query starts with an empty frontier and
    ``done=True``, so it never relaxes, sends, or counts in any statistic.

    ``seed_dist`` is the TRACED warm-start input ([P, K, block] stacked /
    [K, block] per shard, or None for the cold +inf start): per-vertex
    upper bounds produced by a ``warm_init`` stage. Every finitely-seeded
    vertex starts ACTIVE — a seeded value must still be relaxed *from*,
    otherwise a neighbor whose shortest path runs through it could get
    stuck above its true distance. The source bit is min-scattered to 0 on
    top of the seed, so the monotone pipeline reaches the same fixpoint as
    the cold start, just from a much closer initialization.
    """
    block = sh.block
    n_parts = sh.n_parts
    sources = jnp.asarray(sources, jnp.int32)
    nq = int(sources.shape[0])
    if q_valid is None:
        q_valid = jnp.ones((nq,), bool)
    else:
        q_valid = jnp.asarray(q_valid, bool)
    owner = sources // block
    local = sources % block
    qi = jnp.arange(nq)

    if vmapped:
        Pn = n_parts
        if seed_dist is None:
            dist = (jnp.full((Pn, nq, block), INF, jnp.float32)
                    .at[owner, qi, local].set(jnp.where(q_valid, 0.0, INF)))
            active = (jnp.zeros((Pn, nq, block), bool)
                      .at[owner, qi, local].set(q_valid))
        else:
            dist = seed_dist.at[owner, qi, local].min(
                jnp.where(q_valid, 0.0, INF))
            active = jnp.isfinite(dist) & q_valid[None, :, None]
        e_all = sh.loc_w.shape[1] + sh.cut_w.shape[1]
        pruned = jnp.zeros((Pn, e_all), bool)
        last_sent = jnp.full((Pn, nq, sh.slot_owner.shape[1]), INF, jnp.float32)
        cursor = jnp.zeros((Pn,), jnp.int32)
        zeroq = jnp.zeros((Pn, nq), jnp.int32)
        toka2 = jax.vmap(lambda r: _toka2_init_batch(r, nq))(
            jnp.arange(Pn, dtype=jnp.int32))
        done = jnp.broadcast_to(~q_valid, (Pn, nq))
    else:
        mine = (owner == rank) & q_valid
        if seed_dist is None:
            dist = (jnp.full((nq, block), INF, jnp.float32)
                    .at[qi, local].set(jnp.where(mine, 0.0, INF)))
            active = jnp.zeros((nq, block), bool).at[qi, local].set(mine)
        else:
            dist = seed_dist.at[qi, local].min(jnp.where(mine, 0.0, INF))
            active = jnp.isfinite(dist) & q_valid[:, None]
        e_all = sh.loc_w.shape[0] + sh.cut_w.shape[0]
        pruned = jnp.zeros((e_all,), bool)
        last_sent = jnp.full((nq, sh.slot_owner.shape[0]), INF, jnp.float32)
        cursor = jnp.zeros((), jnp.int32)
        zeroq = jnp.zeros((nq,), jnp.int32)
        toka2 = _toka2_init_batch(rank, nq)
        done = ~q_valid

    if cfg.prune_offline_passes > 0:
        off = partial(trishla.prune_offline, n_passes=cfg.prune_offline_passes)
        if vmapped:
            pruned = jax.vmap(off)(sh.loc_w, sh.cut_w, sh.tri_uj, sh.tri_ui,
                                   sh.tri_ij, sh.tri_valid)
        else:
            pruned = off(sh.loc_w, sh.cut_w, sh.tri_uj, sh.tri_ui, sh.tri_ij,
                         sh.tri_valid)

    fstate = None
    fp = cfg.fault_plan
    if fp is not None:
        # one queue slot per flat payload position of the resolved
        # exchange: block for the dense modes, P*C for the bucket routing
        if phases.resolve("exchange", cfg.exchange).dense:
            n_msgs = block
        else:
            n_msgs = n_parts * sh.recv_idx.shape[-1]
        fstate = faults_mod.init_state(fp, nq, n_msgs,
                                       n_parts if vmapped else None)

    ex_stage = phases.resolve("exchange", cfg.exchange)
    inflight = None
    if getattr(ex_stage, "deferred", False):
        # empty (+inf) in-flight buffers: round 0's recv delivers nothing,
        # round 0's sends arrive in round async_lag (ring distance for the
        # hop transport) — the generalized form of the fused round's
        # incoming rotation, deferring the exchange itself
        inflight = ex_stage.init_inflight(sh, nq, cfg, vmapped)

    incoming = front_any = None
    if _round_mode(sh, cfg) == "fused":
        # the fused carry holds last round's delivered-but-unmerged
        # messages; an all-INF batch makes round 0's merge the identity
        # (base case of the bit-identity induction with the staged round)
        C = sh.recv_idx.shape[-1]
        dense = phases.resolve("exchange", cfg.exchange).dense
        if vmapped:
            shape = (n_parts, nq, block) if dense else (n_parts, nq,
                                                        n_parts, C)
        else:
            shape = (nq, block) if dense else (nq, n_parts, C)
        incoming = jnp.full(shape, INF, jnp.float32)
        front_any = jnp.any(active, axis=-1)

    return _Carry(dist=dist, active=active, pruned=pruned, tri_cursor=cursor,
                  last_sent=last_sent, msg_count=zeroq, toka2=toka2, done=done,
                  rounds=jnp.zeros((), jnp.int32), q_rounds=zeroq,
                  relaxations=zeroq, msgs_sent=zeroq, msgs_recv=zeroq,
                  faults=fstate, streak=zeroq, stale=zeroq, resent=zeroq,
                  incoming=incoming, front_any=front_any, inflight=inflight,
                  overlap=jnp.zeros((), jnp.int32),
                  comm_bytes=jnp.zeros((), jnp.int32))


# --------------------------------------------------------------------------
# fixpoint certificate
# --------------------------------------------------------------------------
#
# "One extra relax round produces no improvement" — the exact convergence
# test gating QueryResult.status in the engine. Distances computed by ANY
# run of the monotone pipeline are upper bounds on the true fixpoint d*
# (every finite value is a realized path length); if dist >= d* and
# dist != d*, then some single edge relaxation improves some vertex. The
# certificate therefore relaxes EVERY edge once — local and cut, ignoring
# frontiers, last_sent floors, and even Trishla pruning (a pruned edge
# can never be the sole witness, but including it costs nothing and keeps
# the check independent of the pruning logic) — and reports, per query,
# whether anything improved. No improvement <=> dist IS the fixpoint.

def _cert_relax_shard(shard: SsspShards, dist):
    """One unmasked relaxation of this shard's edges from ``dist`` [K, block].

    Returns (new_local [K, block] after local-edge relaxation, dense cut
    payload [K, P, block]); the caller min-combines the exchanged payloads
    with the local result and compares against ``dist``."""
    S = shard.slot_owner.shape[0]
    d_src = jnp.take(dist, shard.loc_src, axis=1, mode="fill",
                     fill_value=float("inf"))
    new = jax.vmap(lambda d, c: d.at[shard.loc_dst].min(c, mode="drop"))(
        dist, d_src + shard.loc_w)
    d_cut = jnp.take(dist, shard.cut_src, axis=1, mode="fill",
                     fill_value=float("inf"))
    slot_val = jax.vmap(lambda c: jax.ops.segment_min(
        c, shard.cut_seg, num_segments=S,
        indices_are_sorted=True))(d_cut + shard.cut_w)
    slot_val = jnp.where(shard.slot_valid, slot_val, INF)
    return new, _scatter_dense(shard, slot_val, dist.shape[1])


def certificate_improved_sim(sh: SsspShards, dist):
    """Certificate over the stacked sim state: ``dist`` [P, K, block] ->
    ``improved`` [K] bool (True = NOT at the fixpoint)."""
    comm = SimComm(sh.n_parts)
    new, payload = jax.vmap(_cert_relax_shard)(sh, dist)
    merged = jnp.minimum(new, comm.exchange_pmin(payload))
    return jnp.any(merged < dist, axis=(0, 2))


def build_shmap_certificate(sh_spec: SsspShards, mesh, axis_names,
                            on_trace=None):
    """Jitted ``fn(shards_stacked, dist [P, K, block]) -> improved [K]``
    running the certificate under shard_map (one pmin + one or-reduce on
    the wire). ``on_trace`` mirrors the solver's compile accounting but
    feeds the engine's SEPARATE certificate counter — tests pin
    ``trace_counts`` to solver traces only."""
    axes = tuple(axis_names)
    comm = ShmapComm(axes)

    def body(sh_local: SsspShards, dist_loc):
        sh1 = jax.tree_util.tree_map(lambda x: x[0], sh_local)
        d = dist_loc[0]
        new, payload = _cert_relax_shard(sh1, d)
        merged = jnp.minimum(new, comm.exchange_pmin(payload))
        return or_reduce(jnp.any(merged < d, axis=-1), axes)

    pspec = P(axes)
    in_specs = (jax.tree_util.tree_map(lambda _: pspec, sh_spec), pspec)
    shm = compat.shard_map(body, mesh=mesh, in_specs=in_specs,
                           out_specs=P(), check_vma=False)

    def run(stacked, dist):
        if on_trace is not None:
            on_trace(int(dist.shape[1]))
        return shm(stacked, dist)

    return jax.jit(run)


# --------------------------------------------------------------------------
# public entry points
# --------------------------------------------------------------------------

def _as_sources(source_or_sources, n_vertices: int | None = None) -> tuple[int, ...]:
    if isinstance(source_or_sources, (int, np.integer)):
        sources = (int(source_or_sources),)
    else:
        sources = tuple(int(s) for s in source_or_sources)
    if n_vertices is not None:
        for s in sources:
            # an out-of-range id would be silently dropped by the init
            # scatter (all-INF result) or land on a padding vertex
            if not 0 <= s < n_vertices:
                raise ValueError(
                    f"source {s} out of range [0, {n_vertices})")
    return sources


def build_shmap_solver_traced(sh_spec: SsspShards, cfg: SsspConfig, mesh,
                              axis_names, on_trace=None, warm: bool = False):
    """Traced-sources shard_map solver: one compiled program per K.

    Returns a jitted ``fn(shards_stacked, sources [K] i32, q_valid [K] bool)
    -> (dist [P, K, block], stats)``. ``sources`` and ``q_valid`` are traced
    inputs replicated across the mesh — the source bit is scattered inside
    the body, so the SAME compiled program answers arbitrary source batches
    of a given K (the old per-batch recompile is gone). The outer round
    loop is a ``lax.while_loop`` inside the shard_map body; the whole solve
    is one XLA program (this is what the dry-run lowers for the production
    meshes). ``on_trace(K)`` is called once per trace (compile accounting
    for :class:`~repro.core.engine.SsspEngine`).

    ``warm=True`` builds the landmark-seeded variant: the returned fn takes
    a fourth TRACED input ``land [P, L, block]`` (the engine's sharded
    landmark cache, partitioned like the shards) and runs the resolved
    ``warm_init`` stage inside the body — one small [L, K] all-reduce to
    gather the landmark-at-source bounds, then a per-shard seed that
    ``_init_carry`` consumes. Landmark distances stay sharded on the wire;
    only the [L, K] gather is replicated."""
    axes = tuple(axis_names)
    n_parts = sh_spec.n_parts
    comm = ShmapComm(axes)
    warm_stage = phases.resolve("warm_init", cfg.warm_start) if warm else None
    if warm and warm_stage.seed_shard is None:
        raise ValueError(
            f"warm=True needs a seeding warm_init backend; "
            f"cfg.warm_start={cfg.warm_start!r} does not seed")

    def body(sh_local: SsspShards, sources, q_valid, *warm_args):
        sh1 = jax.tree_util.tree_map(lambda x: x[0], sh_local)  # strip P dim
        # recv_idx arrives as [1, P, C] -> [P, C]; inter_edges scalar
        rank = comm.rank()
        seed = None
        if warm:
            land_loc = warm_args[0][0]                   # [L, block]
            seed = warm_stage.seed_shard(land_loc, sources, q_valid, rank,
                                         sh_spec.block, comm.min_all)
        carry = _init_carry(sh1, sources, cfg, rank=rank, vmapped=False,
                            q_valid=q_valid, seed_dist=seed)
        round_fn = _make_round(sh1, cfg, comm, vmapped=False, n_parts=n_parts)

        def cond(c: _Carry):
            return (~jnp.all(c.done)) & (c.rounds < cfg.max_rounds)

        carry = lax.while_loop(cond, round_fn, carry)
        fin = make_finalize(sh1, cfg, comm, vmapped=False)
        dist_final = carry.dist if fin is None else fin(carry)
        dpr = jnp.int32(dispatches_per_round(sh1, cfg))
        stats = SsspStats(
            rounds=carry.rounds,
            relaxations=comm.total(jnp.sum(carry.relaxations)),
            msgs_sent=comm.total(jnp.sum(carry.msgs_sent)),
            msgs_recv=comm.total(jnp.sum(carry.msgs_recv)),
            pruned_edges=comm.total(jnp.sum(carry.pruned).astype(jnp.int32)),
            q_rounds=carry.q_rounds,
            q_relaxations=comm.total(carry.relaxations),
            q_converged=carry.done,
            stale_merges=comm.total(jnp.sum(carry.stale)),
            resends=comm.total(jnp.sum(carry.resent)),
            n_dispatches=carry.rounds * dpr,
            overlap_rounds=carry.overlap,     # globally agreed each round
            bytes_moved=comm.total(carry.comm_bytes))
        return dist_final[None], stats  # restore leading P dim

    pspec = P(axes)
    rspec = P()
    in_specs = jax.tree_util.tree_map(lambda _: pspec, sh_spec)
    in_specs = (in_specs, rspec, rspec) + ((pspec,) if warm else ())
    out_specs = (pspec, SsspStats(*([rspec] * len(SsspStats._fields))))
    shm = compat.shard_map(body, mesh=mesh, in_specs=in_specs,
                           out_specs=out_specs, check_vma=False)

    def run(stacked, sources, q_valid, *warm_args):
        # trace-time side effect: runs once per (K, shard avals) jit entry
        if on_trace is not None:
            on_trace(int(sources.shape[0]))
        return shm(stacked, sources, q_valid, *warm_args)

    return jax.jit(run)


# --------------------------------------------------------------------------
# legacy entry points — thin wrappers over the session engine
#
# The five free functions below predate repro.core.engine.SsspEngine and are
# kept for compatibility; each delegates to a cached engine (engine_for) so
# repeated calls share one compiled program per (K-bucket, cfg). Prefer:
#
#     eng = SsspEngine.build(shards_or_graph, cfg, backend=...)
#     res = eng.solve(sources)          # QueryResult
# --------------------------------------------------------------------------


def solve_sim_batch(sh: SsspShards, sources: Sequence[int],
                    cfg: SsspConfig = SsspConfig()):
    """Single-device simulator, K sources.

    .. deprecated:: delegate of :meth:`SsspEngine.solve` (``backend="sim"``);
       kept for compatibility. Returns (dist [K, n_vertices], SsspStats with
       per-query q_rounds / q_relaxations [K])."""
    from repro.core.engine import engine_for
    res = engine_for(sh, cfg, "sim").solve(sources)
    return res.dist, res.stats


def solve_sim(sh: SsspShards, source: int, cfg: SsspConfig = SsspConfig()):
    """Single-source wrapper: a K=1 batch.

    .. deprecated:: use :meth:`SsspEngine.solve` — this delegates to it."""
    dist, stats = solve_sim_batch(sh, (int(source),), cfg)
    return dist[0], stats


def build_shmap_solver(sh_spec: SsspShards, cfg: SsspConfig, mesh,
                       axis_names, source):
    """Returns a jittable fn(shards_stacked) -> (dist [P, K, block], stats).

    .. deprecated:: the engine's traced solver
       (:func:`build_shmap_solver_traced`) serves ANY source batch of a
       given K from one compiled program; this wrapper bakes ``source``
       into a closure for callers that still expect a fn(shards) handle
       (e.g. the dry-run lowering). No padding is applied: K = len(source).
    """
    from repro.core.engine import engine_for
    sources = _as_sources(source, sh_spec.n_vertices)
    eng = engine_for(sh_spec, cfg, "shmap", mesh, axis_names)
    srcs = np.asarray(sources, np.int32)
    q_valid = np.ones((len(sources),), bool)
    return lambda stacked: eng.shmap_solver(stacked, srcs, q_valid)


def solve_shmap_batch(sh: SsspShards, sources: Sequence[int], cfg: SsspConfig,
                      mesh, axis_names):
    """shard_map backend, K sources. Returns (dist [K, n_vertices], stats).

    .. deprecated:: delegate of :meth:`SsspEngine.solve`
       (``backend="shmap"``); kept for compatibility. Sources are validated
       against ``n_vertices`` exactly like the sim path (out-of-range ids
       raise instead of silently vanishing), and repeated calls reuse the
       engine's compiled solver instead of re-running build_shmap_solver."""
    from repro.core.engine import engine_for
    res = engine_for(sh, cfg, "shmap", mesh, axis_names).solve(sources)
    return res.dist, res.stats


def solve_shmap(sh: SsspShards, source: int, cfg: SsspConfig, mesh, axis_names):
    """Single-source wrapper: a K=1 batch.

    .. deprecated:: use :meth:`SsspEngine.solve` — this delegates to it."""
    dist, stats = solve_shmap_batch(sh, (int(source),), cfg, mesh, axis_names)
    return dist[0], stats
