"""Intra-partition solver — the paper's "Dijkstra within each node".

A binary-heap Dijkstra is inherently serial; the TPU-native equivalent that
preserves the paper's semantics (settle your subgraph to a local fixpoint
before communicating) is iterated *frontier-masked relaxation*:

- ``bellman``: each inner step relaxes all local edges whose source vertex
  improved since the previous step (frontier mask), via gather + scatter-min.
  Runs to local fixpoint inside ``lax.while_loop``.
- ``delta``: Δ-stepping-style near/far ordering — only frontier vertices
  within ``min_active_dist + Δ`` relax each step, reproducing Dijkstra's
  settle-in-distance-order behaviour and avoiding wasted relaxations on
  vertices whose distance will still improve (Meyer & Sanders 2003; the
  paper cites Δ-stepping as the synchronous baseline).

All functions operate on ONE shard's local arrays (no leading P dim); the
driver vmaps (sim backend) or shard_maps (distributed backend) over shards.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

INF = jnp.float32(jnp.inf)


class LocalResult(NamedTuple):
    dist: jax.Array      # [block] f32
    changed: jax.Array   # scalar bool — any local improvement happened
    relaxations: jax.Array  # scalar int32 — edge relaxations performed (TEPS accounting)


def _sweep(dist, frontier, loc_src, loc_dst, loc_w, pruned_loc):
    """One masked relaxation sweep. Returns (dist', new_frontier, n_relax)."""
    block = dist.shape[0]
    src_ok = jnp.take(frontier, loc_src, mode="fill", fill_value=False)
    d_src = jnp.take(dist, loc_src, mode="fill", fill_value=float("inf"))
    w = jnp.where(pruned_loc, INF, loc_w)
    cand = jnp.where(src_ok, d_src + w, INF)
    new = dist.at[loc_dst].min(cand, mode="drop")
    new_frontier = new < dist
    n_relax = jnp.sum(src_ok & (w < INF)).astype(jnp.int32)
    return new, new_frontier, n_relax


def local_fixpoint_bellman(dist, active, loc_src, loc_dst, loc_w, pruned_loc,
                           max_iters: int) -> LocalResult:
    """Relax frontier edges until no local change (the local 'Dijkstra')."""

    def cond(carry):
        _, frontier, it, _, _ = carry
        return jnp.any(frontier) & (it < max_iters)

    def body(carry):
        dist, frontier, it, changed, nrel = carry
        new, new_frontier, n = _sweep(dist, frontier, loc_src, loc_dst, loc_w, pruned_loc)
        return (new, new_frontier, it + 1, changed | jnp.any(new_frontier), nrel + n)

    out = jax.lax.while_loop(
        cond, body, (dist, active, jnp.int32(0), jnp.bool_(False), jnp.int32(0)))
    return LocalResult(dist=out[0], changed=out[3], relaxations=out[4])


def local_fixpoint_delta(dist, active, loc_src, loc_dst, loc_w, pruned_loc,
                         max_iters: int, delta: float) -> LocalResult:
    """Near/far bucketed fixpoint: Dijkstra-order settling without a heap."""

    def cond(carry):
        _, frontier, it, _, _ = carry
        return jnp.any(frontier) & (it < max_iters)

    def body(carry):
        dist, frontier, it, changed, nrel = carry
        fdist = jnp.where(frontier, dist, INF)
        lo = jnp.min(fdist)
        near = frontier & (dist <= lo + delta)
        # always relax at least the nearest bucket; vertices outside stay
        # in the frontier for later buckets
        new, improved, n = _sweep(dist, near, loc_src, loc_dst, loc_w, pruned_loc)
        new_frontier = (frontier & ~near) | improved
        return (new, new_frontier, it + 1, changed | jnp.any(improved), nrel + n)

    out = jax.lax.while_loop(
        cond, body, (dist, active, jnp.int32(0), jnp.bool_(False), jnp.int32(0)))
    return LocalResult(dist=out[0], changed=out[3], relaxations=out[4])


def local_fixpoint(dist, active, loc_src, loc_dst, loc_w, pruned_loc, *,
                   solver: str = "bellman", max_iters: int = 10_000,
                   delta: float = 4.0) -> LocalResult:
    if solver == "bellman":
        return local_fixpoint_bellman(dist, active, loc_src, loc_dst, loc_w,
                                      pruned_loc, max_iters)
    if solver == "delta":
        return local_fixpoint_delta(dist, active, loc_src, loc_dst, loc_w,
                                    pruned_loc, max_iters, delta)
    raise ValueError(f"unknown local solver {solver!r}")
