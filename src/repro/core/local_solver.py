"""Intra-partition solver — the paper's "Dijkstra within each node".

A binary-heap Dijkstra is inherently serial; the TPU-native equivalent that
preserves the paper's semantics (settle your subgraph to a local fixpoint
before communicating) is iterated *frontier-masked relaxation*:

- ``bellman``: each inner step relaxes all local edges whose source vertex
  improved since the previous step (frontier mask), via gather + scatter-min.
  Runs to local fixpoint inside ``lax.while_loop``.
- ``delta``: Δ-stepping-style near/far ordering — only frontier vertices
  within ``min_active_dist + Δ`` relax each step, reproducing Dijkstra's
  settle-in-distance-order behaviour and avoiding wasted relaxations on
  vertices whose distance will still improve (Meyer & Sanders 2003; the
  paper cites Δ-stepping as the synchronous baseline).
- ``pallas``: the dst-tiled Pallas relax kernel
  (``repro.kernels.relax``) run as a fused multi-sweep fixpoint — up to
  ``pallas_sweeps`` frontier-chased sweeps execute inside ONE
  ``pallas_call`` (no XLA re-entry per sweep, no scatter lowering); a thin
  ``lax.while_loop`` re-invokes the kernel on the residual frontier until
  empty. Requires the dst-tiled edge layout precomputed by
  ``build_shards`` (``SsspShards.rx_*``); silently falls back to
  ``bellman`` when the layout is absent.

All functions operate on ONE shard's local arrays (no leading P dim); the
driver vmaps (sim backend) or shard_maps (distributed backend) over shards.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels.relax import relax_fixpoint_pallas

INF = jnp.float32(jnp.inf)


class LocalResult(NamedTuple):
    dist: jax.Array      # [block] f32
    changed: jax.Array   # scalar bool — any local improvement happened
    relaxations: jax.Array  # scalar int32 — edge relaxations performed (TEPS accounting)


def _sweep(dist, frontier, loc_src, loc_dst, loc_w, pruned_loc):
    """One masked relaxation sweep. Returns (dist', new_frontier, n_relax)."""
    block = dist.shape[0]
    src_ok = jnp.take(frontier, loc_src, mode="fill", fill_value=False)
    d_src = jnp.take(dist, loc_src, mode="fill", fill_value=float("inf"))
    w = jnp.where(pruned_loc, INF, loc_w)
    cand = jnp.where(src_ok, d_src + w, INF)
    new = dist.at[loc_dst].min(cand, mode="drop")
    new_frontier = new < dist
    n_relax = jnp.sum(src_ok & (w < INF)).astype(jnp.int32)
    return new, new_frontier, n_relax


def local_fixpoint_bellman(dist, active, loc_src, loc_dst, loc_w, pruned_loc,
                           max_iters: int) -> LocalResult:
    """Relax frontier edges until no local change (the local 'Dijkstra')."""

    def cond(carry):
        _, frontier, it, _, _ = carry
        return jnp.any(frontier) & (it < max_iters)

    def body(carry):
        dist, frontier, it, changed, nrel = carry
        new, new_frontier, n = _sweep(dist, frontier, loc_src, loc_dst, loc_w, pruned_loc)
        return (new, new_frontier, it + 1, changed | jnp.any(new_frontier), nrel + n)

    out = jax.lax.while_loop(
        cond, body, (dist, active, jnp.int32(0), jnp.bool_(False), jnp.int32(0)))
    return LocalResult(dist=out[0], changed=out[3], relaxations=out[4])


def local_fixpoint_delta(dist, active, loc_src, loc_dst, loc_w, pruned_loc,
                         max_iters: int, delta: float) -> LocalResult:
    """Near/far bucketed fixpoint: Dijkstra-order settling without a heap."""

    def cond(carry):
        _, frontier, it, _, _ = carry
        return jnp.any(frontier) & (it < max_iters)

    def body(carry):
        dist, frontier, it, changed, nrel = carry
        fdist = jnp.where(frontier, dist, INF)
        lo = jnp.min(fdist)
        near = frontier & (dist <= lo + delta)
        # always relax at least the nearest bucket; vertices outside stay
        # in the frontier for later buckets
        new, improved, n = _sweep(dist, near, loc_src, loc_dst, loc_w, pruned_loc)
        new_frontier = (frontier & ~near) | improved
        return (new, new_frontier, it + 1, changed | jnp.any(improved), nrel + n)

    out = jax.lax.while_loop(
        cond, body, (dist, active, jnp.int32(0), jnp.bool_(False), jnp.int32(0)))
    return LocalResult(dist=out[0], changed=out[3], relaxations=out[4])


def local_fixpoint_pallas(dist, active, pruned_loc, relax_layout, *,
                          vb: int, max_iters: int, sweeps: int = 8,
                          interpret: bool = True) -> LocalResult:
    """Fused Pallas fixpoint over the precomputed dst-tiled edge layout.

    ``relax_layout`` = (src_t, w_t, dstrel_t, eid_t), each
    [n_vtiles, n_chunks, EB] for THIS shard. Each kernel invocation runs up
    to ``sweeps`` frontier-chased sweeps in one ``pallas_call``; the outer
    ``while_loop`` re-enters only when the residual frontier is non-empty
    (i.e. roughly every ``sweeps``-th XLA step of the bellman path).
    """
    src_t, w_t, dstrel_t, eid_t = relax_layout
    n_vtiles, _, eb = src_t.shape
    block = dist.shape[0]
    bp = n_vtiles * vb
    # pad to the kernel's tile-aligned block; padded slots never win a min
    dist_pad = jnp.full((bp,), INF).at[:block].set(dist)
    front_pad = jnp.zeros((bp,), jnp.float32).at[:block].set(
        active.astype(jnp.float32))
    # gather the runtime pruned mask into tiled edge order (eid sentinel is
    # out of range -> fill 0 = not pruned, i.e. padding stays inert)
    pruned_t = jnp.take(pruned_loc.astype(jnp.int32), eid_t, mode="fill",
                        fill_value=0)

    def cond(c):
        _, front, _, it = c
        return jnp.any(front > 0) & (it < max_iters)

    def body(c):
        d, front, nrel, it = c
        new_d, resid, n = relax_fixpoint_pallas(
            d, front, src_t, w_t, dstrel_t, pruned_t, vb=vb, eb=eb,
            n_sweeps=sweeps, interpret=interpret)
        return new_d, resid, nrel + n, it + jnp.int32(sweeps)

    out = jax.lax.while_loop(
        cond, body, (dist_pad, front_pad, jnp.int32(0), jnp.int32(0)))
    new_dist = out[0][:block]
    return LocalResult(dist=new_dist, changed=jnp.any(new_dist < dist),
                       relaxations=out[2])


def local_fixpoint(dist, active, loc_src, loc_dst, loc_w, pruned_loc, *,
                   solver: str = "bellman", max_iters: int = 10_000,
                   delta: float = 4.0, relax_layout=None, relax_vb: int = 128,
                   pallas_sweeps: int = 8,
                   pallas_interpret: bool = True) -> LocalResult:
    if solver == "pallas" and relax_layout is None:
        solver = "bellman"   # no dst-tiled layout carried by the shards
    if solver == "bellman":
        return local_fixpoint_bellman(dist, active, loc_src, loc_dst, loc_w,
                                      pruned_loc, max_iters)
    if solver == "delta":
        return local_fixpoint_delta(dist, active, loc_src, loc_dst, loc_w,
                                    pruned_loc, max_iters, delta)
    if solver == "pallas":
        return local_fixpoint_pallas(dist, active, pruned_loc, relax_layout,
                                     vb=relax_vb, max_iters=max_iters,
                                     sweeps=pallas_sweeps,
                                     interpret=pallas_interpret)
    raise ValueError(f"unknown local solver {solver!r}")
