"""Intra-partition solver — the paper's "Dijkstra within each node".

A binary-heap Dijkstra is inherently serial; the TPU-native equivalent that
preserves the paper's semantics (settle your subgraph to a local fixpoint
before communicating) is iterated *frontier-masked relaxation*:

- ``bellman``: each inner step relaxes all local edges whose source vertex
  improved since the previous step (frontier mask), via gather + scatter-min.
  Runs to local fixpoint inside ``lax.while_loop``.
- ``delta``: Δ-stepping-style near/far ordering — only frontier vertices
  within ``min_active_dist + Δ`` relax each step, reproducing Dijkstra's
  settle-in-distance-order behaviour and avoiding wasted relaxations on
  vertices whose distance will still improve (Meyer & Sanders 2003; the
  paper cites Δ-stepping as the synchronous baseline).
- ``pallas``: the dst-tiled Pallas relax kernel
  (``repro.kernels.relax``) run as a fused multi-sweep fixpoint — up to
  ``pallas_sweeps`` frontier-chased sweeps execute inside ONE
  ``pallas_call`` (no XLA re-entry per sweep, no scatter lowering); a thin
  ``lax.while_loop`` re-invokes the kernel on the residual frontier until
  empty. Requires the dst-tiled edge layout precomputed by
  ``build_shards`` (``SsspShards.rx_*``); falls back to ``bellman`` with a
  one-time warning when the layout is absent.

All functions operate on ONE shard's local arrays (no leading P dim); the
driver vmaps (sim backend) or shard_maps (distributed backend) over shards.
The driver always presents a leading QUERY axis ``K`` (multi-source
batching) via ``local_fixpoint_batch``: bellman/delta are vmapped over
queries (each query runs its own while_loop lanes; jax lifts the loop
condition to "any query still active"), while the pallas path dispatches
the natively batched kernel whose grid carries the query axis and reuses
one edge-layout stream for all K queries.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import phases
from repro.kernels.relax import (
    relax_fixpoint_batch_pallas, relax_fixpoint_batch_ragged_pallas,
)

INF = jnp.float32(jnp.inf)


class LocalResult(NamedTuple):
    dist: jax.Array      # [block] f32
    changed: jax.Array   # scalar bool — any local improvement happened
    relaxations: jax.Array  # scalar int32 — edge relaxations performed (TEPS accounting)


def _sweep(dist, frontier, loc_src, loc_dst, loc_w, pruned_loc):
    """One masked relaxation sweep. Returns (dist', new_frontier, n_relax)."""
    src_ok = jnp.take(frontier, loc_src, mode="fill", fill_value=False)
    d_src = jnp.take(dist, loc_src, mode="fill", fill_value=float("inf"))
    w = jnp.where(pruned_loc, INF, loc_w)
    cand = jnp.where(src_ok, d_src + w, INF)
    new = dist.at[loc_dst].min(cand, mode="drop")
    new_frontier = new < dist
    n_relax = jnp.sum(src_ok & (w < INF)).astype(jnp.int32)
    return new, new_frontier, n_relax


def local_fixpoint_bellman(dist, active, loc_src, loc_dst, loc_w, pruned_loc,
                           max_iters: int) -> LocalResult:
    """Relax frontier edges until no local change (the local 'Dijkstra')."""

    def cond(carry):
        _, frontier, it, _, _ = carry
        return jnp.any(frontier) & (it < max_iters)

    def body(carry):
        dist, frontier, it, changed, nrel = carry
        new, new_frontier, n = _sweep(dist, frontier, loc_src, loc_dst, loc_w, pruned_loc)
        return (new, new_frontier, it + 1, changed | jnp.any(new_frontier), nrel + n)

    out = jax.lax.while_loop(
        cond, body, (dist, active, jnp.int32(0), jnp.bool_(False), jnp.int32(0)))
    return LocalResult(dist=out[0], changed=out[3], relaxations=out[4])


def local_fixpoint_delta(dist, active, loc_src, loc_dst, loc_w, pruned_loc,
                         max_iters: int, delta: float) -> LocalResult:
    """Near/far bucketed fixpoint: Dijkstra-order settling without a heap."""

    def cond(carry):
        _, frontier, it, _, _ = carry
        return jnp.any(frontier) & (it < max_iters)

    def body(carry):
        dist, frontier, it, changed, nrel = carry
        fdist = jnp.where(frontier, dist, INF)
        lo = jnp.min(fdist)
        near = frontier & (dist <= lo + delta)
        # always relax at least the nearest bucket; vertices outside stay
        # in the frontier for later buckets
        new, improved, n = _sweep(dist, near, loc_src, loc_dst, loc_w, pruned_loc)
        new_frontier = (frontier & ~near) | improved
        return (new, new_frontier, it + 1, changed | jnp.any(improved), nrel + n)

    out = jax.lax.while_loop(
        cond, body, (dist, active, jnp.int32(0), jnp.bool_(False), jnp.int32(0)))
    return LocalResult(dist=out[0], changed=out[3], relaxations=out[4])


def local_fixpoint_pallas(dist, active, pruned_loc, relax_layout, *,
                          vb: int, max_iters: int, sweeps: int = 8,
                          interpret: bool = True) -> LocalResult:
    """Fused Pallas fixpoint over the precomputed dst-tiled edge layout.

    ``relax_layout`` = (src_t, w_t, dstrel_t, eid_t), each
    [n_vtiles, n_chunks, EB] for THIS shard. A K=1 batch: the batched
    wrapper owns the padding / pruned-gather / residual-loop logic.
    """
    res = local_fixpoint_pallas_batch(dist[None], active[None], pruned_loc,
                                      relax_layout, vb=vb,
                                      max_iters=max_iters, sweeps=sweeps,
                                      interpret=interpret)
    return LocalResult(dist=res.dist[0], changed=res.changed[0],
                       relaxations=res.relaxations[0])


def local_fixpoint_pallas_batch(dist, active, pruned_loc, relax_layout, *,
                                vb: int, max_iters: int, sweeps: int = 8,
                                interpret: bool = True) -> LocalResult:
    """Batched pallas fixpoint: dist/active are [K, block]; the dst-tiled
    layout AND the tiled Trishla mask are shared — gathered once, reused by
    every query in the batch (the amortization the batch engine exists for).

    A 5-tuple ``relax_layout`` is the ragged CSR-chunked form (flat chunk
    rows + chunk→tile map) and dispatches the ragged-grid kernel.
    """
    if len(relax_layout) == 5:
        src_t, w_t, dstrel_t, eid_t, ctile = relax_layout
    else:
        src_t, w_t, dstrel_t, eid_t = relax_layout
        ctile = None
    eb = src_t.shape[-1]
    nq, block = dist.shape
    n_vtiles = (src_t.shape[0] if ctile is None else max(-(-block // vb), 1))
    bp = n_vtiles * vb
    # pad to the kernel's tile-aligned block; padded slots never win a min
    dist_pad = jnp.full((nq, bp), INF).at[:, :block].set(dist)
    front_pad = jnp.zeros((nq, bp), jnp.float32).at[:, :block].set(
        active.astype(jnp.float32))
    # gather the runtime pruned mask into tiled edge order (eid sentinel is
    # out of range -> fill 0 = not pruned, i.e. padding stays inert)
    pruned_t = jnp.take(pruned_loc.astype(jnp.int32), eid_t, mode="fill",
                        fill_value=0)

    def cond(c):
        _, front, _, it = c
        return jnp.any(front > 0) & (it < max_iters)

    def body(c):
        d, front, nrel, it = c
        if ctile is None:
            new_d, resid, n = relax_fixpoint_batch_pallas(
                d, front, src_t, w_t, dstrel_t, pruned_t, vb=vb, eb=eb,
                n_sweeps=sweeps, interpret=interpret)
        else:
            new_d, resid, n = relax_fixpoint_batch_ragged_pallas(
                d, front, ctile, src_t, w_t, dstrel_t, pruned_t, vb=vb,
                eb=eb, n_sweeps=sweeps, interpret=interpret)
        return new_d, resid, nrel + n, it + jnp.int32(sweeps)

    out = jax.lax.while_loop(
        cond, body,
        (dist_pad, front_pad, jnp.zeros((nq,), jnp.int32), jnp.int32(0)))
    new_dist = out[0][:, :block]
    return LocalResult(dist=new_dist,
                       changed=jnp.any(new_dist < dist, axis=-1),
                       relaxations=out[2])


# ---- local-solver registry (phase "local_solver") ------------------------
# Uniform batched signature so the driver resolves the backend by name and
# SsspConfig validates it eagerly; every entry returns LocalResult with
# dist [K, block], changed [K], relaxations [K].

@phases.register("local_solver", "bellman")
def _batch_bellman(dist, active, loc_src, loc_dst, loc_w, pruned_loc, *,
                   max_iters, delta, relax_layout, relax_vb, pallas_sweeps,
                   pallas_interpret) -> LocalResult:
    return jax.vmap(partial(local_fixpoint_bellman, loc_src=loc_src,
                            loc_dst=loc_dst, loc_w=loc_w,
                            pruned_loc=pruned_loc,
                            max_iters=max_iters))(dist, active)


@phases.register("local_solver", "delta")
def _batch_delta(dist, active, loc_src, loc_dst, loc_w, pruned_loc, *,
                 max_iters, delta, relax_layout, relax_vb, pallas_sweeps,
                 pallas_interpret) -> LocalResult:
    return jax.vmap(partial(local_fixpoint_delta, loc_src=loc_src,
                            loc_dst=loc_dst, loc_w=loc_w,
                            pruned_loc=pruned_loc, max_iters=max_iters,
                            delta=delta))(dist, active)


@phases.register("local_solver", "pallas")
def _batch_pallas(dist, active, loc_src, loc_dst, loc_w, pruned_loc, *,
                  max_iters, delta, relax_layout, relax_vb, pallas_sweeps,
                  pallas_interpret) -> LocalResult:
    return local_fixpoint_pallas_batch(dist, active, pruned_loc, relax_layout,
                                       vb=relax_vb, max_iters=max_iters,
                                       sweeps=pallas_sweeps,
                                       interpret=pallas_interpret)


def local_fixpoint_batch(dist, active, loc_src, loc_dst, loc_w, pruned_loc, *,
                         solver: str = "bellman", max_iters: int = 10_000,
                         delta: float = 4.0, relax_layout=None,
                         relax_vb: int = 128, pallas_sweeps: int = 8,
                         pallas_interpret: bool = True) -> LocalResult:
    """Multi-query local solve: dist/active carry a leading [K] query axis;
    the edge arrays and the pruned mask are per-shard (query-invariant).
    Returns LocalResult with dist [K, block], changed [K], relaxations [K].
    """
    if solver == "pallas" and relax_layout is None:
        phases.warn_once(
            "local_solver.pallas.no_layout",
            "local_solver='pallas' falling back to 'bellman': the shards "
            "carry no dst-tiled edge layout (build_shards was called with "
            "relax_layout=False)")
        solver = "bellman"
    impl = phases.resolve("local_solver", solver)
    return impl(dist, active, loc_src, loc_dst, loc_w, pruned_loc,
                max_iters=max_iters, delta=delta, relax_layout=relax_layout,
                relax_vb=relax_vb, pallas_sweeps=pallas_sweeps,
                pallas_interpret=pallas_interpret)


def local_fixpoint(dist, active, loc_src, loc_dst, loc_w, pruned_loc, *,
                   solver: str = "bellman", max_iters: int = 10_000,
                   delta: float = 4.0, relax_layout=None, relax_vb: int = 128,
                   pallas_sweeps: int = 8,
                   pallas_interpret: bool = True) -> LocalResult:
    """Single-query local solve: a K=1 batch (the batched entry point owns
    the solver dispatch and the pallas-layout fallback rule)."""
    res = local_fixpoint_batch(
        dist[None], active[None], loc_src, loc_dst, loc_w, pruned_loc,
        solver=solver, max_iters=max_iters, delta=delta,
        relax_layout=relax_layout, relax_vb=relax_vb,
        pallas_sweeps=pallas_sweeps, pallas_interpret=pallas_interpret)
    return LocalResult(dist=res.dist[0], changed=res.changed[0],
                       relaxations=res.relaxations[0])
