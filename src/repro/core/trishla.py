"""Trishla — triangle-inequality edge elimination (paper Algorithm 1).

For a triangle u→v_i, v_i→v_j, u→v_j: if ``w(u,v_j) > w(u,v_i) + w(v_i,v_j)``
the direct edge (u, v_j) cannot lie on any shortest path (the detour through
v_i is strictly shorter) and is deleted.

Correctness: every deleted edge is replaced by a strictly shorter 2-edge
path; deletions can cascade but each replacement is strictly shorter, so by
induction shortest-path distances are preserved exactly.

Two modes:
- ``prune_offline``: one vectorized pass over all candidate triangles
  (host/accelerator preprocessing). Iterated to a fixpoint it also catches
  chains revealed by earlier deletions — but a single pass is already sound.
- ``prune_chunk``: evaluates a fixed-size *chunk* of triangle candidates —
  this is the unit of "useful idle work" the paper assigns to processes that
  have no SSSP messages; the SP-Async driver runs it in the idle branch of
  ``lax.cond``, overlapping pruning with other shards' SSSP exactly as in
  the paper.

Edge ids index the shard's combined edge view: ``[loc_w ++ cut_w]``.
"""
from __future__ import annotations

import jax.numpy as jnp

INF = jnp.float32(jnp.inf)


def effective_weights(loc_w, cut_w, pruned):
    w = jnp.concatenate([loc_w, cut_w])
    return jnp.where(pruned, INF, w)


def prune_pass(w_all, pruned, tri_uj, tri_ui, tri_ij, tri_valid):
    """One full vectorized Trishla pass. Returns the new pruned mask."""
    w = jnp.where(pruned, INF, w_all)
    drop = tri_valid & (w[tri_uj] > w[tri_ui] + w[tri_ij])
    new_pruned = pruned.at[tri_uj].max(drop, mode="drop")
    return new_pruned


def prune_offline(loc_w, cut_w, tri_uj, tri_ui, tri_ij, tri_valid,
                  n_passes: int = 1):
    """Vectorized offline pruning (per shard). pruned: [e_loc + e_cut]."""
    pruned = jnp.zeros(loc_w.shape[0] + cut_w.shape[0], bool)
    w_all = jnp.concatenate([loc_w, cut_w])
    for _ in range(n_passes):
        pruned = prune_pass(w_all, pruned, tri_uj, tri_ui, tri_ij, tri_valid)
    return pruned


def prune_chunk(w_all, pruned, cursor, tri_uj, tri_ui, tri_ij, tri_valid,
                chunk: int):
    """Evaluate triangles [cursor, cursor+chunk) — the idle-work unit.

    Returns (pruned', cursor', n_pruned). Wraps around so repeated idleness
    keeps re-checking (later deletions can enable earlier ones).
    """
    T = tri_uj.shape[0]
    idx = (cursor + jnp.arange(chunk, dtype=jnp.int32)) % jnp.int32(max(T, 1))
    uj = tri_uj[idx]
    ui = tri_ui[idx]
    ij = tri_ij[idx]
    v = tri_valid[idx]
    w = jnp.where(pruned, INF, w_all)
    drop = v & (w[uj] > w[ui] + w[ij])
    new_pruned = pruned.at[uj].max(drop, mode="drop")
    n_pruned = jnp.sum(new_pruned) - jnp.sum(pruned)
    return new_pruned, (cursor + chunk) % jnp.int32(max(T, 1)), n_pruned.astype(jnp.int32)
