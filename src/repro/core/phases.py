"""Backend registry for the SP-Async round pipeline.

The outer round is a fixed sequence of phases — local solve, send pack,
exchange, merge, termination — but each phase has interchangeable
*backends* (e.g. the send pack can run as XLA ``segment_min`` or as the
slot-tiled Pallas kernel). This module is the small registry that maps
``(phase, backend_name) -> implementation`` so:

- ``SsspConfig`` can validate every backend name EAGERLY at construction
  (a typo raises ``ValueError`` listing the valid names instead of failing
  deep inside tracing),
- the solver builds its round by resolution, never by ``if`` ladders, and
  new stages/backends (query caching, landmark reuse, new exchange modes)
  slot in with a ``@register(...)`` decorator without touching the loop.

Registered phases and their config keys:

  ============== ======================= ====================================
  phase          config key              backends
  ============== ======================= ====================================
  round          ``cfg.round``           staged | fused
  local_solver   ``cfg.local_solver``    bellman | delta | pallas
  send           ``cfg.send_backend``    xla | pallas
  exchange       ``cfg.exchange``        bucket | pmin | a2a_dense | async
                                         | async_bucket | async_ppermute
  merge          ``cfg.merge_backend``   xla | pallas
  toka           ``cfg.toka``            toka0 | toka1 | toka2 | toka3
  warm_init      ``cfg.warm_start``      none | landmark
  ============== ======================= ====================================

The ``async*`` exchanges are DEFERRED: the round never barriers on their
collective — round r's relax overlaps delivery of round r-1's sends,
merged one round late (``async``/``async_bucket``: double-buffered
all-to-all, ``cfg.async_lag`` buffers; ``async_ppermute``: bidirectional
``ppermute`` neighbor hops over the partition ring). Registered in
``sssp.py`` next to the synchronous stages.

``round`` selects the SHAPE of the pipeline rather than one phase's
implementation: ``staged`` dispatches local/send/exchange/merge as
separate programs (4 data-plane dispatches per round); ``fused`` runs
merge + local fixpoint + send pack as ONE Pallas megakernel
(``kernels/round``), leaving 2 dispatches (megakernel + exchange) and
making the ``local_solver``/``send_backend``/``merge_backend`` keys
moot for the fused rounds.

Implementations live next to the machinery they use (``local_solver.py``
registers the local solvers, ``sssp.py`` the send/exchange/merge/toka
stages); this module stays dependency-free so anything may import it.
"""
from __future__ import annotations

import warnings

_REGISTRY: dict[str, dict[str, object]] = {}


def register(phase: str, name: str):
    """Decorator: register ``obj`` as backend ``name`` of ``phase``."""

    def deco(obj):
        _REGISTRY.setdefault(phase, {})[name] = obj
        return obj

    return deco


def resolve(phase: str, name: str):
    """Look up a backend; unknown names raise a ``ValueError`` that names
    the valid options (this is what makes ``SsspConfig`` validation eager
    and its errors actionable)."""
    impls = _REGISTRY.get(phase, {})
    if name not in impls:
        raise ValueError(
            f"unknown {phase} backend {name!r}; valid: {sorted(impls)}")
    return impls[name]


def backends(phase: str) -> tuple[str, ...]:
    """Registered backend names for a phase (stable order)."""
    return tuple(sorted(_REGISTRY.get(phase, ())))


def validate(phase: str, name: str) -> str:
    """``resolve`` for its side effect only; returns ``name`` unchanged."""
    resolve(phase, name)
    return name


# -------------------------------------------------------------------------
# one-time warnings (pallas backends silently degrading to XLA would hide
# a perf cliff; warn once per process, not once per trace)
# -------------------------------------------------------------------------

_WARNED: set[str] = set()


def warn_once(key: str, message: str) -> None:
    if key in _WARNED:
        return
    _WARNED.add(key)
    warnings.warn(message, UserWarning, stacklevel=3)
