"""Session-oriented SSSP query engine: build once, stream queries.

The ROADMAP's serving story made concrete: ``SsspEngine`` is the ONE public
surface over the SP-Async solver. It owns the partitioned shards, the
resolved :class:`~repro.core.sssp.RoundPipeline`, and a per-engine compile
cache, replacing five free functions with divergent signatures
(``solve_sim`` / ``solve_sim_batch`` / ``solve_shmap`` /
``solve_shmap_batch`` / ``build_shmap_solver`` — now thin deprecated
wrappers that delegate here).

    eng = SsspEngine.build(graph_or_shards, cfg, backend="sim")
    res = eng.solve([3, 17, 1999])        # QueryResult
    h = eng.submit(42); eng.submit([7, 9])
    eng.drain()                           # coalesced, bucketed batches
    h.result().dist

Compile reuse — the engine's core contract
------------------------------------------

``sources`` is a TRACED input on both backends (scattered inside the
program by ``_init_carry``, never baked into the trace), so one compiled
program per (K-bucket, cfg) serves ARBITRARY source sets. ``solve`` pads
any batch up to the next power-of-two bucket: padded rows start with an
empty frontier and ``done=True``, so they never relax, send, or count in
any statistic — padded-bucket results are bit-identical to the unpadded
solve (queries are independent along the vmapped/batched query axis). The
per-source launch overhead that dominates GPU/MPI Dijkstra once the graph
is resident (arXiv:2504.03667) is paid once per bucket shape, not once per
query batch; this is what the old shmap path got wrong (a fresh XLA
compile per ``solve_shmap_batch`` call, sources baked into the body).

Trace accounting is first-class: every trace of the round (sim) or the
whole-solve program (shmap) bumps ``engine.trace_counts[K]`` — the compile
-reuse tests and the ``engine_serving`` benchmark assert on it directly.

Streaming arrivals
------------------

``submit`` enqueues a query (or query batch) and returns a
:class:`QueryHandle`; ``drain`` coalesces everything pending into
bucketed batches of at most ``max_bucket`` queries (whole handles are
never split across batches) and solves them. ``handle.result()`` drains
on demand, so a caller may also just submit and ask.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import phases
from repro.core.shards import SsspShards, build_shards, shard_distance_rows
from repro.core.sssp import (SimComm, SsspConfig, SsspStats, _as_sources,
                             _init_carry, _make_round,
                             build_shmap_certificate,
                             build_shmap_solver_traced,
                             certificate_improved_sim, dispatches_per_round,
                             make_finalize)
from repro.core.warmstart import CachedRow, LandmarkCache, ResultCache


def bucket_k(k: int) -> int:
    """Bucket policy: the next power of two >= k (so at most 2x padding,
    and a stream of ragged batch sizes folds onto O(log K) compiled
    programs)."""
    if k < 1:
        raise ValueError("at least one source is required")
    return 1 << (k - 1).bit_length()


@dataclasses.dataclass(frozen=True)
class QueryResult:
    """Structured result of one solved (sub)batch.

    ``dist``/``q_rounds``/``q_relaxations`` are views over the REAL queries
    (padded bucket rows already sliced away); ``stats`` carries the same
    per-query columns plus the aggregate totals. ``compile_s`` is the
    cold-start cost (first invocation of this bucket's program, tracing and
    XLA compilation included) and is 0.0 on warm calls.

    ``status`` replaces the old silent ``max_rounds`` truncation:

    - ``"converged"``  — every query passed the fixpoint certificate (one
      extra unmasked relax round produced no improvement); distances are
      exact.
    - ``"max_rounds"`` — the round budget ran out before the detectors
      fired for some query; distances are upper bounds.
    - ``"degraded"``   — a detector declared termination but the
      certificate found a remaining improvement (e.g. a dropped message
      under ``FaultPlan(resend_period=0)``); distances are upper bounds.

    Per-query resolution lives in ``stats.q_converged`` /
    :attr:`q_converged`. Non-converged results are never admitted to the
    result LRU or the landmark cache."""

    dist: np.ndarray            # [K, n_vertices] per-query distances
    sources: tuple              # the K query sources, as submitted
    stats: SsspStats            # aggregates + per-query q_rounds/q_relaxations
    bucket_k: int               # compiled batch shape (0: fully cache-served)
    backend: str                # "sim" | "shmap"
    wall_s: float               # end-to-end solve wall time
    compile_s: float            # cold-start time (0.0 when warm)
    compiled: bool              # True iff this call traced a new program
    cache_hits: int = 0         # queries answered from the result cache
    warm_started: bool = False  # landmark-seeded (vs cold +inf) init
    status: str = "converged"   # converged | max_rounds | degraded

    @property
    def q_rounds(self) -> np.ndarray:
        return np.asarray(self.stats.q_rounds)

    @property
    def q_relaxations(self) -> np.ndarray:
        return np.asarray(self.stats.q_relaxations)

    @property
    def q_converged(self) -> np.ndarray:
        return np.asarray(self.stats.q_converged)

    @property
    def overlap_fraction(self) -> float:
        """Fraction of rounds where in-flight async payload coexisted with
        local relax work — the measurable form of the deferred exchanges'
        communication/computation overlap claim. 0.0 for synchronous
        exchanges, zero-round (fully cache-served) solves, or results
        predating the counter."""
        if self.stats.overlap_rounds is None:
            return 0.0
        rounds = int(self.stats.rounds)
        return float(int(self.stats.overlap_rounds)) / rounds if rounds else 0.0


class QueryHandle:
    """A submitted-but-possibly-unsolved query batch; ``result()`` drains
    the owning engine on demand."""

    __slots__ = ("sources", "_engine", "_result")

    def __init__(self, engine: "SsspEngine", sources: tuple):
        self.sources = sources
        self._engine = engine
        self._result: QueryResult | None = None

    @property
    def done(self) -> bool:
        return self._result is not None

    def result(self) -> QueryResult:
        if self._result is None:
            self._engine.drain()
        return self._result

    def __repr__(self):
        state = "done" if self.done else "pending"
        return f"QueryHandle(sources={self.sources}, {state})"


class SsspEngine:
    """One per-graph session: owns the shards, the resolved phase pipeline,
    and the compiled programs that answer query streams against them."""

    def __init__(self, shards: SsspShards, cfg: SsspConfig, backend: str,
                 mesh=None, axis_names=None, max_bucket: int = 16,
                 result_cache: int = 0, certify: bool = True):
        if backend not in ("sim", "shmap"):
            raise ValueError(f"unknown backend {backend!r}; valid: "
                             "['shmap', 'sim']")
        if backend == "shmap" and (mesh is None or axis_names is None):
            raise ValueError("backend='shmap' requires mesh and axis_names")
        self.shards = shards
        self.cfg = cfg
        self.backend = backend
        self.mesh = mesh
        self.axis_names = tuple(axis_names) if axis_names else None
        self.max_bucket = int(max_bucket)
        self._pending: list[QueryHandle] = []
        self.batches_served = 0
        self.queries_served = 0
        # warm-start cache hierarchy (see core/warmstart.py): the result
        # LRU serves exact repeats with ZERO rounds; the landmark cache
        # (precompute_landmarks) seeds every other query's dist with
        # triangle-inequality upper bounds when cfg.warm_start="landmark".
        # graph_epoch keys both: bumping it (invalidate_caches) orphans
        # every cached row without a scan.
        self.graph_epoch = 0
        self.result_cache = ResultCache(result_cache)
        self.landmarks: LandmarkCache | None = None
        self._warm_stage = phases.resolve("warm_init", cfg.warm_start)
        if self._warm_stage.seed_stacked is not None:
            # counted like the round program: the seed's jit entries are
            # per (L, K) shape, and its first trace is a real compile that
            # must show up in compiled/compile_s (the shmap warm program
            # counts via on_trace; keep the accounting symmetric)
            seed_stacked = self._warm_stage.seed_stacked

            def counted_seed(land, sources, q_valid):
                self._note_trace(int(sources.shape[0]))
                return seed_stacked(land, sources, q_valid)

            self._warm_seed = jax.jit(counted_seed)
        else:
            self._warm_seed = None
        self._warm_solver = None        # lazily built shmap warm program
        self._warm_traced: set = set()  # (K-bucket, L) warm-program traces
        # per-engine compile cache: ONE jitted program per backend whose
        # jit cache holds one entry per K-bucket; trace_counts[K] counts
        # them (a trace-time side effect, so reuse is directly assertable)
        self.trace_counts: dict[int, int] = {}
        self._compile_s: dict[int, float] = {}
        # fixpoint certificate: one extra unmasked relax round over the
        # final distances gates QueryResult.status. Its program is traced
        # once per bucket but counted SEPARATELY (cert_traces) — the
        # compile-reuse tests pin trace_counts to solver traces only.
        self.certify = bool(certify)
        self.cert_traces = 0
        self._cert_shmap = None     # lazily built shmap certificate
        if backend == "sim":
            base_round = _make_round(shards, cfg, SimComm(shards.n_parts),
                                     vmapped=True, n_parts=shards.n_parts)

            def counted_round(carry):
                self._note_trace(int(carry.dist.shape[1]))
                return base_round(carry)

            def counted_cert(dist_pk):
                self.cert_traces += 1
                return certificate_improved_sim(shards, dist_pk)

            self.round_fn = jax.jit(counted_round)
            self._cert_fn = jax.jit(counted_cert)
            # fused round / deferred (async) exchange: the loop can exit
            # with delivered-but-unmerged messages in carry.incoming and
            # undelivered payload in carry.inflight (see sssp.make_finalize)
            fin = make_finalize(shards, cfg, SimComm(shards.n_parts),
                                vmapped=True)
            self._finalize_fn = jax.jit(fin) if fin is not None else None
            self.shmap_solver = None
        else:
            self.round_fn = None
            self._cert_fn = None
            self._finalize_fn = None
            self.shmap_solver = build_shmap_solver_traced(
                shards, cfg, mesh, self.axis_names, on_trace=self._note_trace)

    # ---------------------------------------------------------- build ----

    @classmethod
    def build(cls, graph_or_shards, cfg: SsspConfig | None = None,
              backend: str = "sim", mesh=None, axis_names=None, *,
              n_parts: int = 8, max_bucket: int = 16, result_cache: int = 0,
              certify: bool = True, **shard_kwargs) -> "SsspEngine":
        """Create a session over a :class:`SsspShards` (used as-is) or a
        :class:`~repro.graph.structure.Graph` (partitioned here with
        ``n_parts`` and any ``build_shards`` keyword). ``result_cache``
        sizes the exact-repeat LRU (0 = disabled, the bit-compatible
        default: every solve runs the full pipeline)."""
        if isinstance(graph_or_shards, SsspShards):
            if shard_kwargs:
                raise ValueError("shard build options only apply when "
                                 "building from a Graph")
            sh = graph_or_shards
        else:
            sh = build_shards(graph_or_shards, n_parts, **shard_kwargs)
        return cls(sh, cfg or SsspConfig(), backend, mesh, axis_names,
                   max_bucket=max_bucket, result_cache=result_cache,
                   certify=certify)

    @property
    def n_vertices(self) -> int:
        return self.shards.n_vertices

    @property
    def n_parts(self) -> int:
        return self.shards.n_parts

    @property
    def trace_count(self) -> int:
        """Total traces across every bucket program this engine compiled."""
        return sum(self.trace_counts.values())

    def _note_trace(self, kb: int) -> None:
        self.trace_counts[kb] = self.trace_counts.get(kb, 0) + 1

    # ---------------------------------------------------------- solve ----

    def _warm_active(self) -> bool:
        """True when solves should seed from the landmark cache: the config
        opted in AND a cache for the CURRENT graph epoch exists."""
        return (self._warm_stage.needs_landmarks
                and self.landmarks is not None
                and self.landmarks.epoch == self.graph_epoch)

    def solve(self, sources, *, bucket: bool = True) -> QueryResult:
        """Solve a source batch (int or sequence). Pads to the next
        power-of-two K-bucket (``bucket=False`` keeps K exact — same
        results bit-for-bit, one extra compiled shape) and answers from
        the bucket's compiled program.

        With a result cache enabled, exact repeats of a source (within the
        current graph epoch) are answered from the LRU with ZERO rounds,
        and cached sources are stripped from the batch BEFORE padding — a
        partially-cached batch rides a smaller bucket. Cached rows report
        ``q_rounds == 0`` (this call did no work for them); distances are
        the stored rows, bit-identical to the solve that produced them."""
        srcs = _as_sources(sources, self.shards.n_vertices)
        if len(srcs) < 1:
            raise ValueError("at least one source is required")
        if self.result_cache.maxsize == 0:
            return self._solve_batch(srcs, bucket=bucket)
        return self._solve_cached(srcs, bucket=bucket)

    def _solve_batch(self, srcs: tuple, *, bucket: bool = True,
                     use_warm: bool = True) -> QueryResult:
        """Run the compiled pipeline for ``srcs`` (no result-cache layer).
        ``use_warm=False`` forces the cold +inf init — used to solve the
        landmark pivots themselves."""
        k = len(srcs)
        kb = bucket_k(k) if bucket else k
        src_arr = np.zeros((kb,), np.int32)
        src_arr[:k] = srcs
        q_valid = np.zeros((kb,), bool)
        q_valid[:k] = True
        warm = use_warm and self._warm_active()

        traces0 = self.trace_count
        t0 = time.perf_counter()
        compile_s = 0.0
        if self.backend == "sim":
            seed = None
            if warm:
                tc = time.perf_counter()
                seed = self._warm_seed(self.landmarks.dist,
                                       jnp.asarray(src_arr),
                                       jnp.asarray(q_valid))
                if self.trace_count > traces0:
                    jax.block_until_ready(seed)
                    compile_s += time.perf_counter() - tc
            if warm:
                # solve-time coverage, keyed (bucket, L) like the shmap
                # path: the seed program is separate from the round, so a
                # cold trace of this bucket does not make the warm path
                # compile-free (warmup() consults this set)
                self._warm_traced.add((kb, self.landmarks.n_landmarks))
            carry = _init_carry(self.shards, src_arr, self.cfg, rank=None,
                                vmapped=True, q_valid=q_valid,
                                seed_dist=seed)
            r = 0
            traces_loop = self.trace_count
            while r < self.cfg.max_rounds:
                fresh = self.trace_count == traces_loop
                tc = time.perf_counter()
                carry = self.round_fn(carry)
                if fresh and self.trace_count > traces_loop:
                    jax.block_until_ready(carry)
                    compile_s += time.perf_counter() - tc
                r += 1
                if bool(np.asarray(carry.done).all()):
                    break
            dist_pk = carry.dist
            if self._finalize_fn is not None:
                dist_pk = self._finalize_fn(carry)
            done_k = np.asarray(carry.done)[0][:k]  # globally agreed
            # [P, K, block] -> per-query global distance vectors
            dist = np.moveaxis(np.asarray(dist_pk), 0, 1)
            dist = dist.reshape(kb, -1)[:k, : self.shards.n_vertices]
            stats = SsspStats(
                rounds=carry.rounds,
                relaxations=np.sum(carry.relaxations, dtype=np.int32),
                msgs_sent=np.sum(carry.msgs_sent, dtype=np.int32),
                msgs_recv=np.sum(carry.msgs_recv, dtype=np.int32),
                pruned_edges=np.sum(carry.pruned, dtype=np.int32),
                q_rounds=np.max(np.asarray(carry.q_rounds), axis=0)[:k],
                q_relaxations=np.sum(np.asarray(carry.relaxations),
                                     axis=0)[:k],
                stale_merges=np.sum(np.asarray(carry.stale), dtype=np.int32),
                resends=np.sum(np.asarray(carry.resent), dtype=np.int32),
                n_dispatches=np.int32(
                    int(np.asarray(carry.rounds))
                    * dispatches_per_round(self.shards, self.cfg)),
                overlap_rounds=np.int32(np.asarray(carry.overlap)),
                bytes_moved=np.int32(np.asarray(carry.comm_bytes)))
        else:
            tc = time.perf_counter()
            if warm:
                if self._warm_solver is None:
                    self._warm_solver = build_shmap_solver_traced(
                        self.shards, self.cfg, self.mesh, self.axis_names,
                        on_trace=self._note_trace, warm=True)
                dist_pk, stats = self._warm_solver(self.shards, src_arr,
                                                   q_valid,
                                                   self.landmarks.dist)
                # coverage recorded at SOLVE time, keyed (bucket, L): the
                # warm program is distinct from the cold solver AND its
                # jit entries depend on the landmark aval; recording at
                # trace time would go stale when a jit-cache hit skips the
                # trace (e.g. re-precompute with the same pivot count)
                self._warm_traced.add((kb, self.landmarks.n_landmarks))
            else:
                dist_pk, stats = self.shmap_solver(self.shards, src_arr,
                                                   q_valid)
            jax.block_until_ready(dist_pk)
            if self.trace_count > traces0:
                compile_s = time.perf_counter() - tc
            done_k = np.asarray(stats.q_converged)[:k]
            dist = np.moveaxis(np.asarray(dist_pk), 0, 1)   # [K, P, block]
            dist = dist.reshape(kb, -1)[:k, : self.shards.n_vertices]
            stats = stats._replace(q_rounds=stats.q_rounds[:k],
                                   q_relaxations=stats.q_relaxations[:k])

        # fixpoint certificate: the detectors' word (done_k) is a claim;
        # one extra unmasked relax round is the proof. Certified truth
        # overrides the detector in BOTH directions — a run that exhausted
        # max_rounds at the fixpoint is converged, a detector that fired
        # over a dropped message is not.
        if self.certify:
            if self.backend == "sim":
                improved = np.asarray(self._cert_fn(dist_pk))[:k]
            else:
                if self._cert_shmap is None:
                    self._cert_shmap = build_shmap_certificate(
                        self.shards, self.mesh, self.axis_names,
                        on_trace=lambda _k: setattr(
                            self, "cert_traces", self.cert_traces + 1))
                improved = np.asarray(
                    self._cert_shmap(self.shards, dist_pk))[:k]
            q_conv = ~improved
        else:
            q_conv = done_k.copy()
        if bool(q_conv.all()):
            status = "converged"
        elif bool((~q_conv & ~done_k).any()):
            status = "max_rounds"
        else:
            status = "degraded"
        stats = stats._replace(q_converged=q_conv)

        wall_s = time.perf_counter() - t0
        compiled = self.trace_count > traces0
        if compiled:
            self._compile_s[kb] = compile_s
        self.batches_served += 1
        self.queries_served += k
        return QueryResult(dist=dist, sources=srcs, stats=stats, bucket_k=kb,
                           backend=self.backend, wall_s=wall_s,
                           compile_s=compile_s, compiled=compiled,
                           warm_started=warm, status=status)

    def _solve_cached(self, srcs: tuple, *, bucket: bool) -> QueryResult:
        """Result-cache layer over ``_solve_batch``: strip the sources the
        LRU can answer (and in-batch duplicates) BEFORE bucket padding,
        solve the remainder, then reassemble rows in submitted order."""
        t0 = time.perf_counter()
        epoch = self.graph_epoch
        hits: dict[int, CachedRow] = {}
        uncached: list[int] = []
        for s in dict.fromkeys(srcs):
            row = self.result_cache.get(s, epoch)
            if row is None:
                uncached.append(s)
            else:
                hits[s] = row
        raw = None
        if uncached:
            raw = self._solve_batch(tuple(uncached), bucket=bucket)
            for i, s in enumerate(uncached):
                # graceful degradation: only certified-converged rows may
                # enter the LRU — a degraded/max_rounds row is an upper
                # bound, and a cache would launder it into later batches
                # as if it were exact
                if not bool(raw.stats.q_converged[i]):
                    continue
                # copy: a view would pin the whole [kb, n] batch array in
                # the LRU for as long as any one of its rows stays cached
                self.result_cache.put(s, epoch,
                                      CachedRow(dist=raw.dist[i].copy()))
        raw_col = {s: i for i, s in enumerate(uncached)}

        k = len(srcs)
        dist = np.empty((k, self.shards.n_vertices), np.float32)
        q_rounds = np.zeros((k,), np.int32)
        q_relax = np.zeros((k,), np.int32)
        q_conv = np.ones((k,), bool)    # LRU rows were certified on entry
        n_hit = 0
        for j, s in enumerate(srcs):
            if s in hits:
                dist[j] = hits[s].dist
                n_hit += 1
            else:
                i = raw_col[s]
                dist[j] = raw.dist[i]
                q_rounds[j] = raw.q_rounds[i]
                q_relax[j] = raw.q_relaxations[i]
                q_conv[j] = bool(raw.stats.q_converged[i])
        zero = np.int32(0)
        if raw is not None:
            stats = raw.stats._replace(q_rounds=q_rounds,
                                       q_relaxations=q_relax,
                                       q_converged=q_conv)
        else:
            # every source served from the LRU: zero rounds, no program run
            stats = SsspStats(rounds=zero, relaxations=zero, msgs_sent=zero,
                              msgs_recv=zero, pruned_edges=zero,
                              q_rounds=q_rounds, q_relaxations=q_relax,
                              q_converged=q_conv, stale_merges=zero,
                              resends=zero, n_dispatches=zero,
                              overlap_rounds=zero, bytes_moved=zero)
            self.batches_served += 1
        # _solve_batch already counted the uncached subset it ran
        self.queries_served += k - len(uncached)
        return QueryResult(
            dist=dist, sources=srcs, stats=stats,
            bucket_k=raw.bucket_k if raw is not None else 0,
            backend=self.backend, wall_s=time.perf_counter() - t0,
            compile_s=raw.compile_s if raw is not None else 0.0,
            compiled=raw.compiled if raw is not None else False,
            cache_hits=n_hit,
            warm_started=raw.warm_started if raw is not None else False,
            status=raw.status if raw is not None else "converged")

    # ------------------------------------------------------ warm start ----

    def precompute_landmarks(self, l_sources) -> LandmarkCache:
        """Solve the L pivot sources once (cold) and cache their distances
        sharded ``[L, block]`` per shard — ``4 B x L x block`` per shard.
        With ``cfg.warm_start="landmark"`` every later solve seeds its
        distance vector with ``min_l(land[l, src] + land[l, v])`` instead
        of +inf and converges in fewer rounds, bit-identically. The pivot
        rows also populate the result cache (a landmark solve IS an exact
        solve of its pivot).

        REQUIRES symmetric distances (``d(u, v) == d(v, u)``, true for
        every undirected generator in :mod:`repro.graph.generators`): on a
        directed graph the bound uses ``d(l, src)`` where the triangle
        inequality needs ``d(src, l)``, and an invalid (too-low) seed
        would be silently kept by the monotone pipeline. The solved pivot
        rows give the ``L x L`` cross-distance matrix for free, so
        detectable asymmetry raises here instead of corrupting solves —
        a necessary check, not a sufficient one (a directed graph can be
        symmetric between the sampled pivots yet asymmetric elsewhere)."""
        srcs = _as_sources(l_sources, self.shards.n_vertices)
        if len(srcs) < 1:
            raise ValueError("at least one landmark source is required")
        res = self._solve_batch(tuple(dict.fromkeys(srcs)), use_warm=False)
        # landmark rows seed EVERY later solve: admit only certified
        # fixpoints (a degraded pivot row could under-bound d(l, src) +
        # d(l, v) nowhere but over-bound it everywhere — still wrong as a
        # "converges bit-identically" warm start), and never NaN (one NaN
        # seed poisons every distance downstream of it)
        if res.status != "converged":
            raise ValueError(
                f"landmark precompute did not converge (status="
                f"{res.status!r}): refusing to cache non-fixpoint seeds — "
                "raise max_rounds or fix the fault/termination config")
        if np.isnan(res.dist).any():
            raise ValueError(
                "landmark precompute produced NaN distances: the seed rows "
                "are not finite upper bounds (check edge weights)")
        cross = res.dist[:, list(res.sources)]      # [L, L] pivot pairs
        if not np.allclose(cross, cross.T, rtol=1e-4, atol=1e-4):
            raise ValueError(
                "landmark warm start requires symmetric distances, but the "
                "pivot cross-distances are asymmetric (directed graph?): "
                "the triangle-inequality seed would not be an upper bound")
        land = shard_distance_rows(res.dist, self.shards.n_parts,
                                   self.shards.block)
        self.landmarks = LandmarkCache(sources=res.sources, dist=land,
                                       epoch=self.graph_epoch)
        for i, s in enumerate(res.sources):
            self.result_cache.put(s, self.graph_epoch,
                                  CachedRow(dist=res.dist[i].copy()))
        return self.landmarks

    def invalidate_caches(self) -> int:
        """Graph-epoch bump: orphans every result-cache row and drops the
        landmark cache. Call after mutating the underlying graph/shards —
        cached distances are state that must not survive a graph change
        (the SSSP-Del invalidation story). Returns the new epoch."""
        self.graph_epoch += 1
        self.result_cache.clear()
        self.landmarks = None
        self._warm_traced.clear()
        return self.graph_epoch

    def warmup(self, k: int = 1) -> float:
        """Compile the bucket program serving batches of size ``k`` ahead
        of traffic; returns the cold-start seconds (0.0 if already warm).
        Bypasses the result cache (repeated sources must not shrink the
        compiled shape below the requested bucket). Warms the programs
        traffic will actually ride: on a landmark-warm engine that
        includes the warm path (the shmap whole-solve warm program / the
        sim seed program), which a cold trace of the same bucket (e.g.
        from ``precompute_landmarks``) does not cover."""
        kb = bucket_k(k)
        if self._warm_active():
            already = (kb, self.landmarks.n_landmarks) in self._warm_traced
        else:
            already = self.trace_counts.get(kb, 0) > 0
        if already:
            return 0.0
        res = self._solve_batch((0,) * kb, bucket=False)
        return res.compile_s

    # ------------------------------------------------------- streaming ----

    def submit(self, sources) -> QueryHandle:
        """Enqueue a query (or query batch) for the next ``drain``; sources
        are validated NOW so a bad id fails at submission, not mid-drain."""
        srcs = _as_sources(sources, self.shards.n_vertices)
        if len(srcs) < 1:
            raise ValueError("at least one source is required")
        h = QueryHandle(self, srcs)
        self._pending.append(h)
        return h

    @property
    def pending(self) -> int:
        return len(self._pending)

    def drain(self) -> list[QueryResult]:
        """Coalesce pending arrivals into bucketed batches and solve them.

        Consecutive handles are packed while the combined size stays within
        ``max_bucket``; a handle is never split, so an oversized submission
        simply rides its own (larger) bucket. Each handle receives a
        :class:`QueryResult` view of its own rows; batch-level aggregates
        (rounds, totals, timing) are shared by every handle in the batch.
        If a solve fails mid-drain, every unsolved handle (including the
        failing batch) is re-queued before the error propagates — no
        submission is silently lost."""
        pending, self._pending = self._pending, []
        results: list[QueryResult] = []
        i = 0
        while i < len(pending):
            start = i
            group = [pending[i]]
            total = len(pending[i].sources)
            i += 1
            while (i < len(pending)
                   and total + len(pending[i].sources) <= self.max_bucket):
                group.append(pending[i])
                total += len(pending[i].sources)
                i += 1
            try:
                batch = self.solve([s for h in group for s in h.sources])
            except BaseException:
                self._pending = pending[start:] + self._pending
                raise
            off = 0
            for h in group:
                kk = len(h.sources)
                sl = slice(off, off + kk)
                conv = np.asarray(batch.stats.q_converged)[sl]
                h._result = dataclasses.replace(
                    batch, dist=batch.dist[sl], sources=h.sources,
                    status="converged" if bool(conv.all()) else batch.status,
                    stats=batch.stats._replace(
                        q_rounds=batch.stats.q_rounds[sl],
                        q_relaxations=batch.stats.q_relaxations[sl],
                        q_converged=conv))
                results.append(h._result)
                off += kk
        return results

    def __repr__(self):
        return (f"SsspEngine(backend={self.backend!r}, "
                f"n_vertices={self.n_vertices}, n_parts={self.n_parts}, "
                f"buckets={sorted(self.trace_counts)}, "
                f"pending={self.pending})")


# --------------------------------------------------------------------------
# engine cache backing the legacy free-function wrappers
# --------------------------------------------------------------------------

# One engine per (shards object, cfg, backend, mesh/axes): the legacy
# solve_* wrappers answer many calls against the same partitioned graph and
# must keep the compile-reuse the engine exists for. A cached engine holds
# its shards (and mesh) strongly, so the id() halves of a live entry's key
# cannot be recycled into an alias; the cache is bounded. This replaces the
# old module-global _SIM_ROUND_CACHE — the compiled programs now live in
# the engines.
_ENGINE_CACHE: dict = {}
_ENGINE_CACHE_MAX = 16


def engine_for(sh: SsspShards, cfg: SsspConfig, backend: str = "sim",
               mesh=None, axis_names=None) -> SsspEngine:
    """Cached engine lookup for the legacy wrappers (and anything else that
    holds shards + cfg instead of a session)."""
    axes = tuple(axis_names) if axis_names else None
    key = (id(sh), cfg, backend, None if mesh is None else id(mesh), axes)
    eng = _ENGINE_CACHE.get(key)
    if eng is not None and eng.shards is sh and eng.mesh is mesh:
        return eng
    eng = SsspEngine(sh, cfg, backend, mesh, axes)
    if len(_ENGINE_CACHE) >= _ENGINE_CACHE_MAX:
        _ENGINE_CACHE.pop(next(iter(_ENGINE_CACHE)))
    _ENGINE_CACHE[key] = eng
    return eng
