"""ToKa — termination detection for the asynchronous SSSP (paper §III.D).

Four detectors:

- ``toka0`` (BSP baseline, not in the paper): global quiescence via one
  all-reduce of "any shard still has work". Under a lock-step runtime this
  is exact and cheapest; it is the yardstick the paper's detectors are
  benchmarked against.
- ``toka1`` (paper Algorithm 4): the message-budget heuristic. Each shard
  counts received messages; when ``msg_count >= n_parts * n_inter_edges``
  it votes to stop. The run terminates when every shard has either
  exhausted its budget or the graph is globally quiescent.
- ``toka2`` (paper Algorithm 5): the Dijkstra-Feijen-van-Gasteren /
  Safra-style token ring, executed literally: white/black shard colors +
  send/receive counters; a (state, count, hops) token circulates one hop
  per round over the device ring (``collective-permute`` on ICI); a full
  white, zero-count circuit triggers a red token which every shard must
  observe before the outer loop exits.

- ``toka3`` (the paper's timeout heuristic): terminate after the system
  has been globally inactive — no sends, no receives, no live frontier,
  nothing in flight — for ``T`` consecutive rounds, where ``T`` is
  computed from the inter-edge and partition counts with a safety factor
  (:func:`toka3_bound`). Unlike toka1 it never fires while traffic flows,
  and unlike toka2 it needs no token state — only a per-query streak
  counter. Under a :class:`~repro.core.faults.FaultPlan` the bound gains
  ``fault_slack`` rounds so messages hiding in the delay queue or awaiting
  an anti-entropy resend cannot look like quiescence.

Color convention (paper text): a shard turns BLACK when it *sends* distance
updates and decrements its counter per message sent; it increments the
counter per message received; forwarding the token resets the shard to
white (DFG rule). Under BSP no messages are in flight at round boundaries,
so counters sum to zero globally at every check — the color mechanism does
the real work; counters are kept for fidelity (and would matter on a truly
asynchronous transport).

Deferred (async) exchanges — the truly asynchronous transport the paper
assumes — interact with every detector through the round's termination
view: payload buffered in ``carry.inflight`` sets per-query *pending* bits
that are ORed into the activity mask (exactly like the FaultPlan delay
queue), so no detector can declare quiescence while messages ride the
pipe. toka2's counters now earn their keep: under ``exchange="async"`` the
global sent-received sum stays positive for exactly the in-flight rounds
(Safra's invariant, exercised for real); the dense ``async_ppermute`` runs
the color-only variant, which stays sound because an in-flight message
always sits in SOME shard's transit buffer, and that shard's pending bit
blocks ordinary token forwarding. toka3 additionally widens its bound by
the worst-case delivery lag (see the slack computation in ``sssp.py``).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

WHITE, BLACK, RED = jnp.int32(0), jnp.int32(1), jnp.int32(2)


class Toka2State(NamedTuple):
    color: jax.Array      # int32 scalar (WHITE/BLACK)
    count: jax.Array      # int32 scalar (recv - send, cumulative)
    has_token: jax.Array  # bool scalar
    tok_state: jax.Array  # int32 scalar
    tok_count: jax.Array  # int32 scalar
    tok_hops: jax.Array   # int32 scalar
    seen_red: jax.Array   # bool scalar


class Token(NamedTuple):
    present: jax.Array
    state: jax.Array
    count: jax.Array
    hops: jax.Array


def empty_token():
    return Token(jnp.bool_(False), WHITE, jnp.int32(0), jnp.int32(0))


def toka2_init(rank) -> Toka2State:
    """rank is a traced or concrete scalar; shard 0 starts with the token."""
    has = rank == 0
    return Toka2State(
        color=WHITE, count=jnp.int32(0),
        has_token=jnp.asarray(has),
        tok_state=WHITE, tok_count=jnp.int32(0), tok_hops=jnp.int32(0),
        seen_red=jnp.bool_(False),
    )


def toka2_account(state: Toka2State, sends, recvs) -> Toka2State:
    """Per-round send/receive accounting (paper: blacken+decrement on send,
    increment on receive)."""
    sends = sends.astype(jnp.int32)
    recvs = recvs.astype(jnp.int32)
    color = jnp.where(sends > 0, BLACK, state.color)
    count = state.count - sends + recvs
    return state._replace(color=color, count=count)


def toka2_forward(state: Toka2State, rank, idle, *, n_parts: int) -> tuple[Toka2State, Token]:
    """Decide whether/what to forward this round. Returns (state', outgoing)."""
    P = jnp.int32(n_parts)
    is_init = rank == 0
    holder = state.has_token

    # --- red token: mark seen, always forward (system is already quiescent)
    red_case = holder & (state.tok_state == RED)

    # --- initiator with a returned token (full circuit) and locally idle
    returned = holder & is_init & idle & (state.tok_hops >= P) & ~red_case
    terminate = returned & (state.tok_state == WHITE) & \
        ((state.tok_count + state.count) == 0) & (state.color == WHITE)
    reinit = returned & ~terminate

    # --- initiator launching the first probe (hops == 0) and idle
    launch = holder & is_init & idle & (state.tok_hops == 0) & ~red_case

    # --- ordinary shard forwarding: merge color/count, reset to white
    ordinary = holder & ~is_init & idle & ~red_case

    forwarding = red_case | terminate | reinit | launch | ordinary

    out_state = jnp.where(
        red_case | terminate, RED,
        jnp.where(reinit | launch, WHITE,
                  jnp.maximum(state.tok_state, state.color)))
    out_count = jnp.where(red_case | terminate | reinit | launch,
                          jnp.int32(0), state.tok_count + state.count)
    out_hops = jnp.where(terminate | reinit | launch, jnp.int32(1),
                         state.tok_hops + 1)

    outgoing = Token(present=forwarding, state=out_state,
                     count=out_count, hops=out_hops)

    # forwarding resets the shard to white (DFG); it gives the token away
    new_color = jnp.where(ordinary | reinit | launch, WHITE, state.color)
    new_seen = state.seen_red | (holder & (state.tok_state == RED)) | terminate
    new_state = state._replace(
        color=new_color,
        has_token=holder & ~forwarding,
        seen_red=new_seen,
    )
    return new_state, outgoing


def toka2_absorb(state: Toka2State, incoming: Token) -> Toka2State:
    """Adopt an incoming token (at most one is live in the ring)."""
    take = incoming.present
    return state._replace(
        has_token=state.has_token | take,
        tok_state=jnp.where(take, incoming.state, state.tok_state),
        tok_count=jnp.where(take, incoming.count, state.tok_count),
        tok_hops=jnp.where(take, incoming.hops, state.tok_hops),
        seen_red=state.seen_red | (take & (incoming.state == RED)),
    )


def toka1_vote(msg_count, inter_edges, n_parts: int):
    """Paper Algorithm 4: stop when msg_count >= n_parts * inter_edges."""
    bound = jnp.int32(n_parts) * jnp.maximum(inter_edges.astype(jnp.int32), 1)
    return msg_count >= bound


def toka3_bound(inter_edges, n_parts, safety, fault_slack: int = 0):
    """Quiet-streak timeout (rounds): ``ceil(safety * (1 + log2(1 + P) +
    log2(1 + inter_edges / P))) + fault_slack``.

    The log terms scale the grace period with how long a wavefront can
    plausibly stay silent: token/aggregation latency grows with the
    partition ring (``log2 P``) and revival latency with how much cut
    structure a stray update can reawaken (``log2`` of per-part inter
    edges). ``safety`` is the paper's safety factor; ``fault_slack``
    covers bounded delivery delay + anti-entropy period under fault
    injection. Works on traced or concrete inputs — the shard_map body
    calls it on a traced ``inter_edges``."""
    Pf = jnp.float32(n_parts)
    ie = jnp.asarray(inter_edges).astype(jnp.float32)
    t = jnp.ceil(safety * (1.0 + jnp.log2(1.0 + Pf) + jnp.log2(1.0 + ie / Pf)))
    return t.astype(jnp.int32) + jnp.int32(fault_slack)


def toka3_timeout(inter_edges_total: int, n_parts: int, safety: float = 2.0,
                  fault_slack: int = 0) -> int:
    """Host-side toka3 bound (same formula as :func:`toka3_bound`), for
    tests and tooling that want the concrete round budget."""
    return int(toka3_bound(inter_edges_total, n_parts, safety, fault_slack))
