"""Host-side shard preprocessing for SP-Async.

Splits each partition's edges into LOCAL (dst owned by the same shard) and
CUT (dst owned elsewhere) lists, and precomputes the *static message
routing* for the bucketed boundary exchange:

- Cut edges are grouped (host-side, one-time) by their boundary pair
  ``(dst_owner, dst_local)``. Each unique pair is a *message slot*.
- At runtime a shard segment-mins its cut-edge candidates into the slots
  (pre-aggregation: one message per boundary vertex, not per edge — the
  paper's future-work "message buffering" made static), scatters slots into
  a ``[P, C]`` send buffer at *precomputed static positions*, and fires one
  ``all_to_all``.
- The receive-side index table (which local vertex each incoming slot
  addresses) is also static: ``recv_idx[q, p, c]`` = the local vertex on
  shard q addressed by sender p's slot c. Built here by transposition.

Everything here is one-time host preprocessing — the paper's "Graph
Partition" phase. Besides the routing tables, three Pallas tile layouts
ride in the shards (each an instance of the same pre-tile-by-destination
pattern): ``rx_*`` (local edges by vertex tile, for the relax kernel),
``tx_*`` (cut edges by message-slot tile + the ``tx_payload_slot`` payload
inverse, for the send kernel), and ``mx_*`` (receive positions by vertex
tile, for the merge kernel).

Each layout family exists in two shapes, selected by ``layout=``:

- ``"dense"``: ``[P, n_tiles, n_chunks, EB]`` with ``n_chunks`` the max
  over tiles AND shards — every tile is padded to the worst case. Simple,
  but on power-law graphs (where one vertex tile can carry orders of
  magnitude more edges than the median) almost all of it is padding.
- ``"ragged"``: CSR-chunked — flat ``[P, total_chunks, EB]`` chunk rows
  plus a ``*_ctile [P, total_chunks]`` chunk→tile map consumed by the
  ragged-grid kernels (scalar-prefetched). Memory is proportional to
  ``sum_t ceil(count_t / EB)`` instead of ``n_tiles * max_t ceil(count_t
  / EB)``; values are bit-identical (same stable sort, same chunk split,
  minus inert padding). ``SsspShards.layout_bytes()`` reports both the
  measured bytes and the CSR ideal / dense equivalent for either form.

``build_shards`` materializes the full ``partition_1d`` intermediate —
fine up to ~1M edges. ``build_shards_stream`` consumes an edge-chunk
iterator with per-part accumulators instead, so a 10M-edge graph
partitions without ever holding a ``[P, e_max]`` dense intermediate.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.graph.structure import Graph
from repro.core.partition import partition_1d
from repro.kernels.merge import build_msg_ragged_layout, build_msg_tiled_layout
from repro.kernels.relax import build_dst_ragged_layout, build_dst_tiled_layout
from repro.kernels.send import build_slot_ragged_layout, build_slot_tiled_layout


def _pad2(rows, width, fill, dtype):
    out = np.full((len(rows), width), fill, dtype)
    for i, r in enumerate(rows):
        out[i, : len(r)] = r
    return out


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SsspShards:
    """All static per-shard state for the SP-Async solver, stacked [P, ...]."""

    # local edges (dst owned by this shard)
    loc_src: jax.Array     # [P, e_loc] int32 local ids
    loc_dst: jax.Array     # [P, e_loc] int32 local ids
    loc_w: jax.Array       # [P, e_loc] f32 (+inf padding)
    # cut edges (dst owned elsewhere), grouped by (owner, dst_local)
    cut_src: jax.Array     # [P, e_cut] int32 local ids
    cut_w: jax.Array       # [P, e_cut] f32 (+inf padding)
    cut_seg: jax.Array     # [P, e_cut] int32 -> slot segment id (S = padded)
    # message slots (unique boundary pairs)
    slot_owner: jax.Array  # [P, S] int32 destination shard
    slot_dstl: jax.Array   # [P, S] int32 dst-local id on the destination shard
    slot_pos: jax.Array    # [P, S] int32 position within the [P, C] send row
    slot_valid: jax.Array  # [P, S] bool
    # receive routing: local vertex addressed by (sender, bucket position)
    recv_idx: jax.Array    # [P, P, C] int32 (block = invalid sentinel)
    # Trishla triangle candidates: edge-id triples (uj to prune, ui, ij)
    tri_uj: jax.Array      # [P, T] int32 -> index into the *combined* edge view
    tri_ui: jax.Array      # [P, T] int32
    tri_ij: jax.Array      # [P, T] int32
    tri_valid: jax.Array   # [P, T] bool
    # ToKa1 bound inputs
    inter_edges: jax.Array  # [P] int32 per-shard cut-edge counts
    n_vertices: int = dataclasses.field(metadata=dict(static=True))
    n_parts: int = dataclasses.field(metadata=dict(static=True))
    block: int = dataclasses.field(metadata=dict(static=True))
    # dst-tiled layout of the LOCAL edges for the Pallas relax kernel
    # (built once at partition time; None when relax_layout=False). The
    # tiled slots are a permutation of [0, e_loc) plus padding; rx_eid maps
    # each slot back to its local edge id (sentinel = e_loc) so the runtime
    # Trishla pruned mask can be gathered into tiled order per solve.
    # Dense layout: [P, n_vtiles, n_chunks, EB]. Ragged layout: flat
    # [P, total_chunks, EB] chunk rows plus the rx_ctile chunk→tile map.
    rx_src: jax.Array | None = None
    rx_w: jax.Array | None = None
    rx_dstrel: jax.Array | None = None
    rx_eid: jax.Array | None = None
    rx_ctile: jax.Array | None = None   # [P, total_chunks] int32 (ragged only;
    #                                     sentinel n_vtiles = inert padding)
    rx_vb: int = dataclasses.field(default=128, metadata=dict(static=True))
    rx_eb: int = dataclasses.field(default=512, metadata=dict(static=True))
    # slot-tiled layout of the CUT edges for the Pallas send kernel (same
    # dst-tiled pattern with the message SLOT in the destination role;
    # None when comm_layout=False). tx_eid maps tiled slots back to cut
    # edge ids (sentinel = e_cut) for the runtime Trishla pruned gather.
    tx_src: jax.Array | None = None
    tx_w: jax.Array | None = None
    tx_segrel: jax.Array | None = None
    tx_eid: jax.Array | None = None
    tx_ctile: jax.Array | None = None   # [P, total_chunks] int32 (ragged only)
    # static inverse of (slot_owner, slot_pos): the slot feeding each
    # bucketed payload position, so the payload scatter becomes a gather
    tx_payload_slot: jax.Array | None = None  # [P, P, C] int32 (sentinel = S)
    tx_sb: int = dataclasses.field(default=128, metadata=dict(static=True))
    tx_eb: int = dataclasses.field(default=512, metadata=dict(static=True))
    # msg-tiled receive routing for the Pallas merge kernel: flat incoming
    # positions [0, P*C) grouped by destination vertex tile
    mx_pos: jax.Array | None = None
    mx_dstrel: jax.Array | None = None
    mx_valid: jax.Array | None = None
    mx_ctile: jax.Array | None = None   # [P, total_chunks] int32 (ragged only)
    mx_vb: int = dataclasses.field(default=128, metadata=dict(static=True))
    mx_eb: int = dataclasses.field(default=512, metadata=dict(static=True))
    # which tile-layout family the rx/tx/mx arrays use ("dense" | "ragged")
    layout: str = dataclasses.field(default="dense",
                                    metadata=dict(static=True))

    @property
    def e_loc(self):
        return self.loc_src.shape[1]

    @property
    def e_cut(self):
        return self.cut_src.shape[1]

    @property
    def n_slots(self):
        return self.slot_owner.shape[1]

    @property
    def bucket_cap(self):
        return self.recv_idx.shape[2]

    @property
    def has_relax_layout(self):
        return self.rx_src is not None

    @property
    def relax_layout(self):
        """Per-call tuple consumed by ``local_fixpoint_batch`` (or None).
        Ragged shards append the chunk→tile map (5-tuple vs 4-tuple) —
        consumers dispatch the ragged kernels on the arity."""
        if self.rx_src is None:
            return None
        base = (self.rx_src, self.rx_w, self.rx_dstrel, self.rx_eid)
        return base if self.rx_ctile is None else base + (self.rx_ctile,)

    @property
    def has_send_layout(self):
        return self.tx_src is not None

    @property
    def send_layout(self):
        """Per-call tuple consumed by the pallas send stage (or None);
        5-tuple (with chunk→tile map) when ragged."""
        if self.tx_src is None:
            return None
        base = (self.tx_src, self.tx_w, self.tx_segrel, self.tx_eid)
        return base if self.tx_ctile is None else base + (self.tx_ctile,)

    @property
    def has_merge_layout(self):
        return self.mx_pos is not None

    @property
    def merge_layout(self):
        """Per-call tuple consumed by the pallas merge stage (or None);
        4-tuple (with chunk→tile map) when ragged."""
        if self.mx_pos is None:
            return None
        base = (self.mx_pos, self.mx_dstrel, self.mx_valid)
        return base if self.mx_ctile is None else base + (self.mx_ctile,)

    def layout_bytes(self):
        """Measured memory of each tile-layout family vs the CSR ideal and
        the dense-padded equivalent.

        Per family: ``bytes`` (actual array storage), ``items`` (real
        edges / messages it encodes), ``bytes_per_item``, ``ideal_bytes``
        (CSR lower bound: 4 B per plane per item — 4 planes for the edge
        layouts, 3 for the msg layout), and ``dense_bytes`` (what the
        worst-case-padded dense layout costs for the same data; equals
        ``bytes`` when the shards ARE dense). Top-level ``bytes_per_edge``
        divides the edge layouts (relax + send) by real edge count — the
        number the CI scale gate holds within 1.5x of the 16 B/edge ideal.
        """
        loc_edges = int(np.isfinite(np.asarray(self.loc_w)).sum())
        cut_edges = int(np.isfinite(np.asarray(self.cut_w)).sum())
        msgs = int((np.asarray(self.recv_idx) < self.block).sum())

        def _bytes(arrays):
            return int(sum(np.asarray(a).size * np.asarray(a).dtype.itemsize
                           for a in arrays if a is not None))

        def _dense_bytes(arrays, ctile, n_tiles, eb, planes):
            """Dense equivalent: P * n_tiles * max-chunks-anywhere * EB."""
            if arrays[0] is None:
                return 0
            if ctile is None:
                return _bytes(arrays)                  # already dense
            ct = np.asarray(ctile)
            max_chunks = 1
            for p in range(ct.shape[0]):
                real = ct[p][ct[p] < n_tiles]
                if real.size:
                    per_tile = np.bincount(real, minlength=n_tiles)
                    max_chunks = max(max_chunks, int(per_tile.max()))
            P = ct.shape[0]
            return int(P * n_tiles * max_chunks * eb * planes * 4)

        n_vtiles = max(-(-self.block // self.rx_vb), 1)
        n_stiles = max(-(-self.n_slots // self.tx_sb), 1)
        n_mtiles = max(-(-self.block // self.mx_vb), 1)
        groups = {}
        for name, arrays, ctile, items, planes, n_tiles, eb in (
            ("relax", (self.rx_src, self.rx_w, self.rx_dstrel, self.rx_eid,
                       self.rx_ctile), self.rx_ctile, loc_edges, 4,
             n_vtiles, self.rx_eb),
            ("send", (self.tx_src, self.tx_w, self.tx_segrel, self.tx_eid,
                      self.tx_ctile), self.tx_ctile, cut_edges, 4,
             n_stiles, self.tx_eb),
            ("merge", (self.mx_pos, self.mx_dstrel, self.mx_valid,
                       self.mx_ctile), self.mx_ctile, msgs, 3,
             n_mtiles, self.mx_eb),
        ):
            b = _bytes(arrays)
            groups[name] = {
                "bytes": b,
                "items": items,
                "bytes_per_item": b / max(items, 1),
                "ideal_bytes": items * planes * 4,
                "dense_bytes": _dense_bytes(arrays, ctile, n_tiles, eb,
                                            planes),
            }
        edge_bytes = groups["relax"]["bytes"] + groups["send"]["bytes"]
        n_edges = loc_edges + cut_edges
        return {
            "layout": self.layout,
            "groups": groups,
            "total_bytes": sum(g["bytes"] for g in groups.values()),
            "dense_bytes": sum(g["dense_bytes"] for g in groups.values()),
            "n_edges": n_edges,
            "bytes_per_edge": edge_bytes / max(n_edges, 1),
            "ideal_bytes_per_edge": 16.0,   # 4 planes x 4 B, each edge in
            #                                 exactly one edge layout
        }


def shard_distance_rows(rows, n_parts: int, block: int) -> jax.Array:
    """Re-shard host distance rows into the carry's per-shard layout.

    ``rows``: [L, n_vertices] (e.g. the L solved landmark sources) ->
    ``[P, L, block]`` with +inf on the padding vertices, matching how the
    solver's ``dist`` is blocked across shards. This is the storage layout
    of the engine's landmark cache — 4 B x L x block per shard — chosen so
    the warm-init seed is a per-shard broadcast against the resident
    ``dist`` block, with no runtime re-partitioning."""
    rows = np.asarray(rows, np.float32)
    n_land, n = rows.shape
    full = np.full((n_land, n_parts * block), np.inf, np.float32)
    full[:, :n] = rows
    return jnp.asarray(np.swapaxes(full.reshape(n_land, n_parts, block), 0, 1))


def _check_weights(w, valid):
    """Raise on NaN / non-finite / negative weights among the valid edges.

    A NaN weight propagates through every min it touches, and a negative
    weight breaks the monotonicity the whole async pipeline (and its
    termination proofs) rests on — both would otherwise surface only as
    silently wrong fixpoints. Padding edges legitimately carry +inf, so
    only the valid edges are checked."""
    bad_nan = valid & np.isnan(w)
    bad_inf = valid & ~np.isnan(w) & ~np.isfinite(w)
    bad_neg = valid & (w < 0)
    if bad_nan.any() or bad_inf.any() or bad_neg.any():
        raise ValueError(
            f"invalid edge weights: {int(bad_nan.sum())} NaN, "
            f"{int(bad_inf.sum())} non-finite, {int(bad_neg.sum())} "
            "negative — SSSP requires finite non-negative weights")


def _check_endpoints(src, dst, valid, n_vertices):
    """Raise on out-of-range endpoints among the valid edges.

    An out-of-range id would silently land in the wrong shard (owner =
    id // block) or alias a padding slot — like a bad weight, it corrupts
    the fixpoint instead of failing. Same counted-error style as the
    weight check."""
    bad_src = valid & ((src < 0) | (src >= n_vertices))
    bad_dst = valid & ((dst < 0) | (dst >= n_vertices))
    if bad_src.any() or bad_dst.any():
        raise ValueError(
            f"out-of-range edge endpoints: {int(bad_src.sum())} src, "
            f"{int(bad_dst.sum())} dst — vertex ids must lie in "
            f"[0, {n_vertices})")


def build_shards(g: Graph, n_parts: int, max_triangles_per_part: int | None = None,
                 enumerate_triangles: bool = True, relax_layout: bool = True,
                 relax_vb: int = 128, relax_eb: int = 512,
                 comm_layout: bool = True, send_sb: int = 128,
                 send_eb: int = 512, merge_vb: int = 128,
                 merge_eb: int = 512, layout: str = "dense") -> SsspShards:
    """Partition + preprocess a materialized ``Graph`` (see module doc).

    ``layout`` selects the tile-layout family for the rx/tx/mx arrays:
    ``"dense"`` (worst-case padded) or ``"ragged"`` (CSR-chunked)."""
    w_all = np.asarray(g.weight)
    v_all = np.asarray(g.valid)
    _check_weights(w_all, v_all)
    _check_endpoints(np.asarray(g.src), np.asarray(g.dst), v_all,
                     g.n_vertices)
    pg = partition_1d(g, n_parts)
    P, block, n = pg.n_parts, pg.block, pg.n_vertices

    src_l = np.asarray(pg.src_local)
    dst_o = np.asarray(pg.dst_owner)
    dst_l = np.asarray(pg.dst_local)
    w = np.asarray(pg.weight)
    valid = np.asarray(pg.valid)

    parts = []
    for p in range(P):
        vm = valid[p]
        parts.append((src_l[p][vm], dst_o[p][vm], dst_l[p][vm], w[p][vm]))
    return _assemble_shards(
        parts, n, P, block,
        max_triangles_per_part=max_triangles_per_part,
        enumerate_triangles=enumerate_triangles, relax_layout=relax_layout,
        relax_vb=relax_vb, relax_eb=relax_eb, comm_layout=comm_layout,
        send_sb=send_sb, send_eb=send_eb, merge_vb=merge_vb,
        merge_eb=merge_eb, layout=layout)


def build_shards_stream(edge_chunks, n_vertices: int, n_parts: int, *,
                        dedup: bool = True,
                        max_triangles_per_part: int | None = None,
                        enumerate_triangles: bool = False,
                        relax_layout: bool = True, relax_vb: int = 128,
                        relax_eb: int = 512, comm_layout: bool = True,
                        send_sb: int = 128, send_eb: int = 512,
                        merge_vb: int = 128, merge_eb: int = 512,
                        layout: str = "ragged") -> SsspShards:
    """Streaming shard build: consume an iterator of ``(src, dst, w)``
    edge chunks instead of a materialized ``Graph``.

    Each chunk is validated (weights + endpoints, same errors as
    ``build_shards``) and routed to its owner part (``src // block``)
    immediately, so peak memory is one chunk plus the per-part
    accumulators — never the global sorted edge list or the rectangular
    ``[P, e_max]`` ``partition_1d`` intermediate a 10M-edge graph would
    blow up on. Per part, edges are then (src, dst)-sorted and min-weight
    deduped with EXACTLY the ``csr_from_coo`` recipe, so the resulting
    shards are bit-identical to ``build_shards(csr_from_coo(...), ...)``
    on the concatenated chunks.

    ``enumerate_triangles`` defaults to False here (unlike ``build_shards``)
    — Trishla's host-side triangle enumeration is superlinear and not meant
    for the graph sizes this entry point exists for. ``layout`` defaults to
    ``"ragged"`` for the same reason."""
    block = max(-(-n_vertices // n_parts), 1)
    acc_src = [[] for _ in range(n_parts)]
    acc_dst = [[] for _ in range(n_parts)]
    acc_w = [[] for _ in range(n_parts)]
    for src, dst, w in edge_chunks:
        src = np.asarray(src, np.int64)
        dst = np.asarray(dst, np.int64)
        w = np.asarray(w, np.float32)
        ok = np.ones(len(src), bool)
        _check_weights(w, ok)
        _check_endpoints(src, dst, ok, n_vertices)
        owner = src // block
        for p in np.unique(owner):
            m = owner == p
            acc_src[p].append(src[m])
            acc_dst[p].append(dst[m])
            acc_w[p].append(w[m])

    parts = []
    for p in range(n_parts):
        if acc_src[p]:
            src = np.concatenate(acc_src[p])
            dst = np.concatenate(acc_dst[p])
            w = np.concatenate(acc_w[p]).astype(np.float32)
        else:
            src = np.zeros(0, np.int64)
            dst = np.zeros(0, np.int64)
            w = np.zeros(0, np.float32)
        acc_src[p] = acc_dst[p] = acc_w[p] = None     # free as we go
        # mirror csr_from_coo exactly: (src, dst) sort, then min-weight
        # dedup by (key, weight) sort + keep-first — bit-identity with the
        # batch path depends on reproducing this ordering verbatim
        order = np.lexsort((dst, src))
        src, dst, w = src[order], dst[order], w[order]
        if dedup and len(src):
            key = src * n_vertices + dst
            o2 = np.lexsort((w, key))
            key, src, dst, w = key[o2], src[o2], dst[o2], w[o2]
            keep = np.ones(len(key), bool)
            keep[1:] = key[1:] != key[:-1]
            src, dst, w = src[keep], dst[keep], w[keep]
        dst_o = dst // block
        parts.append((src - p * block, dst_o, dst - dst_o * block, w))
    return _assemble_shards(
        parts, n_vertices, n_parts, block,
        max_triangles_per_part=max_triangles_per_part,
        enumerate_triangles=enumerate_triangles, relax_layout=relax_layout,
        relax_vb=relax_vb, relax_eb=relax_eb, comm_layout=comm_layout,
        send_sb=send_sb, send_eb=send_eb, merge_vb=merge_vb,
        merge_eb=merge_eb, layout=layout)


def _assemble_shards(parts, n, P, block, *, max_triangles_per_part,
                     enumerate_triangles, relax_layout, relax_vb, relax_eb,
                     comm_layout, send_sb, send_eb, merge_vb, merge_eb,
                     layout) -> SsspShards:
    """Shared assembly: per-part valid edges -> SsspShards.

    ``parts[p]`` = (src_local, dst_owner, dst_local, w), each the part's
    VALID edges in (src, dst)-sorted order (both builders guarantee it)."""
    if layout not in ("dense", "ragged"):
        raise ValueError(f"unknown layout {layout!r}: expected 'dense' or "
                         "'ragged'")

    loc_rows_src, loc_rows_dst, loc_rows_w = [], [], []
    cut_rows_src, cut_rows_w, cut_rows_seg = [], [], []
    slot_rows_owner, slot_rows_dstl = [], []
    inter_edges = np.zeros(P, np.int64)

    for p in range(P):
        p_src, p_do, p_dl, p_w = parts[p]
        cm = p_do != p
        lm = ~cm
        loc_rows_src.append(p_src[lm])
        loc_rows_dst.append(p_dl[lm])
        loc_rows_w.append(p_w[lm])
        # group cut edges by (owner, dst_local)
        co, cl, cs, cw = p_do[cm], p_dl[cm], p_src[cm], p_w[cm]
        order = np.lexsort((cl, co))
        co, cl, cs, cw = co[order], cl[order], cs[order], cw[order]
        key = co.astype(np.int64) * block + cl
        if len(key):
            new_seg = np.ones(len(key), bool)
            new_seg[1:] = key[1:] != key[:-1]
            seg_id = np.cumsum(new_seg) - 1
            u_owner = co[new_seg]
            u_dstl = cl[new_seg]
        else:
            seg_id = np.zeros(0, np.int64)
            u_owner = np.zeros(0, np.int64)
            u_dstl = np.zeros(0, np.int64)
        cut_rows_src.append(cs)
        cut_rows_w.append(cw)
        cut_rows_seg.append(seg_id)
        slot_rows_owner.append(u_owner)
        slot_rows_dstl.append(u_dstl)
        inter_edges[p] = int(cm.sum())

    e_loc = max(max((len(r) for r in loc_rows_src), default=0), 1)
    e_cut = max(max((len(r) for r in cut_rows_src), default=0), 1)
    S = max(max((len(r) for r in slot_rows_owner), default=0), 1)

    # position of each slot within its destination bucket row
    slot_pos_rows = []
    cap = 1
    for p in range(P):
        owners = slot_rows_owner[p]
        pos = np.zeros(len(owners), np.int64)
        for q in np.unique(owners):
            m = owners == q
            pos[m] = np.arange(m.sum())
            cap = max(cap, int(m.sum()))
        slot_pos_rows.append(pos)
    C = cap

    # receive routing table: recv_idx[q, p, c] = dst_local, built by transpose
    recv_idx = np.full((P, P, C), block, np.int64)
    for p in range(P):
        owners, dstl, pos = slot_rows_owner[p], slot_rows_dstl[p], slot_pos_rows[p]
        recv_idx[owners, p, pos] = dstl

    # ---- Trishla triangle candidates (host-side enumeration) --------------
    # Combined per-shard edge view: local edges [0, e_loc) then cut edges
    # [e_loc, e_loc + e_cut). Triangles (u, vi, vj): u and vi owned by this
    # shard (so (vi, vj) is visible), vj arbitrary, both (u, vi), (u, vj),
    # (vi, vj) present. Candidate to prune: (u, vj).
    tri_rows = [[] for _ in range(P)]
    if enumerate_triangles:
        # per-shard edge lookup: (src_local, dst_global) -> combined edge id
        for p in range(P):
            lsrc, ldst, lw = loc_rows_src[p], loc_rows_dst[p], loc_rows_w[p]
            csrc, cw_, cseg = cut_rows_src[p], cut_rows_w[p], cut_rows_seg[p]
            # global dst of cut edges: owner*block + dst_local via slots
            cg = (slot_rows_owner[p][cseg] * block + slot_rows_dstl[p][cseg]) if len(cseg) else np.zeros(0, np.int64)
            all_src = np.concatenate([lsrc, csrc])            # local u ids
            all_dstg = np.concatenate([ldst + p * block, cg]) # global v ids
            # edge ids must match the runtime combined view, where local
            # edges are PADDED to e_loc before the cut edges are appended
            eid = np.concatenate([np.arange(len(lsrc)),
                                  e_loc + np.arange(len(csrc))])
            # adjacency (by local src) for this shard
            order = np.argsort(all_src, kind="stable")
            s_srt, d_srt, e_srt = all_src[order], all_dstg[order], eid[order]
            starts = np.searchsorted(s_srt, np.arange(block + 1))
            budget = max_triangles_per_part
            tri = tri_rows[p]
            for u in range(block):
                lo, hi = starts[u], starts[u + 1]
                if hi - lo < 2:
                    continue
                nbrs = d_srt[lo:hi]
                nbr_eids = e_srt[lo:hi]
                for a in range(len(nbrs)):
                    vi = nbrs[a]
                    if vi // block != p:
                        continue  # (vi, vj) must be visible: vi owned here
                    vi_loc = vi - p * block
                    vlo, vhi = starts[vi_loc], starts[vi_loc + 1]
                    vi_out = d_srt[vlo:vhi]
                    vi_out_eids = e_srt[vlo:vhi]
                    # intersect N(u) and N(vi)
                    common, ia, ib = np.intersect1d(nbrs, vi_out, return_indices=True)
                    for t in range(len(common)):
                        vj = common[t]
                        if vj == u + p * block or vj == vi:
                            continue
                        tri.append((nbr_eids[ia[t]], nbr_eids[a], vi_out_eids[ib[t]]))
                        if budget is not None and len(tri) >= budget:
                            break
                    if budget is not None and len(tri) >= budget:
                        break
                if budget is not None and len(tri) >= budget:
                    break
    T = max(max((len(r) for r in tri_rows), default=0), 1)
    tri_uj = np.full((P, T), 0, np.int64)
    tri_ui = np.full((P, T), 0, np.int64)
    tri_ij = np.full((P, T), 0, np.int64)
    tri_valid = np.zeros((P, T), bool)
    for p in range(P):
        for k, (a, b, c) in enumerate(tri_rows[p]):
            tri_uj[p, k], tri_ui[p, k], tri_ij[p, k] = a, b, c
            tri_valid[p, k] = True

    # ---- dst-tiled layout of the local edges (Pallas relax kernel) --------
    # Built once here — NOT per solve. Per-shard layouts share n_vtiles
    # (same block) but can differ in chunk count; pad to the max so they
    # stack into one [P, n_vtiles, n_chunks, EB] array for the sim backend
    # (the shard_map backend slices its own shard back out).
    rx = dict(rx_src=None, rx_w=None, rx_dstrel=None, rx_eid=None)
    if relax_layout and layout == "ragged":
        # CSR-chunked: each shard keeps only its own ceil(count_t/eb) chunks
        # per tile, flattened to [total_chunks, EB] with a chunk->tile map.
        # Shards stack to [P, total_chunks_max, EB]; padding chunks are
        # inert (w=+inf) and carry the ctile sentinel n_vtiles.
        per_shard = []
        for p in range(P):
            src_r, w_r, dr_r, eid_r, ct_r, block_pad = build_dst_ragged_layout(
                loc_rows_src[p], loc_rows_dst[p], loc_rows_w[p], block,
                vb=relax_vb, eb=relax_eb, with_eid=True)
            per_shard.append((np.asarray(src_r), np.asarray(w_r),
                              np.asarray(dr_r), np.asarray(eid_r),
                              np.asarray(ct_r)))
        n_vtiles = block_pad // relax_vb
        tc = max(lay[0].shape[0] for lay in per_shard)
        rx_src = np.full((P, tc, relax_eb), block_pad - 1, np.int64)
        rx_w = np.full((P, tc, relax_eb), np.inf, np.float32)
        rx_dstrel = np.zeros((P, tc, relax_eb), np.int64)
        rx_eid = np.full((P, tc, relax_eb), e_loc, np.int64)
        rx_ctile = np.full((P, tc), n_vtiles, np.int64)
        for p, (src_r, w_r, dr_r, eid_r, ct_r) in enumerate(per_shard):
            nc = src_r.shape[0]
            rx_src[p, :nc] = src_r
            rx_w[p, :nc] = w_r
            rx_dstrel[p, :nc] = dr_r
            # builder sentinel is the shard's own edge count; restamp to the
            # padded-row sentinel e_loc so the runtime gather is uniform
            eid = eid_r.astype(np.int64)
            eid[eid == len(loc_rows_src[p])] = e_loc
            rx_eid[p, :nc] = eid
            rx_ctile[p, :nc] = ct_r
        rx = dict(rx_src=jnp.asarray(rx_src, jnp.int32),
                  rx_w=jnp.asarray(rx_w, jnp.float32),
                  rx_dstrel=jnp.asarray(rx_dstrel, jnp.int32),
                  rx_eid=jnp.asarray(rx_eid, jnp.int32),
                  rx_ctile=jnp.asarray(rx_ctile, jnp.int32))
    elif relax_layout:
        per_shard = []
        for p in range(P):
            src_t, w_t, dr_t, eid_t, _bp = build_dst_tiled_layout(
                loc_rows_src[p], loc_rows_dst[p], loc_rows_w[p], block,
                vb=relax_vb, eb=relax_eb, with_eid=True)
            per_shard.append((np.asarray(src_t), np.asarray(w_t),
                              np.asarray(dr_t), np.asarray(eid_t)))
        n_vtiles = per_shard[0][0].shape[0]
        block_pad = n_vtiles * relax_vb
        n_chunks = max(lay[0].shape[1] for lay in per_shard)
        rx_src = np.full((P, n_vtiles, n_chunks, relax_eb), block_pad - 1,
                         np.int64)
        rx_w = np.full((P, n_vtiles, n_chunks, relax_eb), np.inf, np.float32)
        rx_dstrel = np.zeros((P, n_vtiles, n_chunks, relax_eb), np.int64)
        rx_eid = np.full((P, n_vtiles, n_chunks, relax_eb), e_loc, np.int64)
        for p, (src_t, w_t, dr_t, eid_t) in enumerate(per_shard):
            nc = src_t.shape[1]
            rx_src[p, :, :nc] = src_t
            rx_w[p, :, :nc] = w_t
            rx_dstrel[p, :, :nc] = dr_t
            # builder sentinel is the shard's own edge count; restamp to the
            # padded-row sentinel e_loc so the runtime gather is uniform
            eid = eid_t.astype(np.int64)
            eid[eid == len(loc_rows_src[p])] = e_loc
            rx_eid[p, :, :nc] = eid
        rx = dict(rx_src=jnp.asarray(rx_src, jnp.int32),
                  rx_w=jnp.asarray(rx_w, jnp.float32),
                  rx_dstrel=jnp.asarray(rx_dstrel, jnp.int32),
                  rx_eid=jnp.asarray(rx_eid, jnp.int32))

    # ---- slot/msg-tiled layouts for the Pallas send + merge kernels -------
    # Same one-time host build as rx_*: per-shard layouts share the tile
    # count (slots padded to S / vertices to block are shard-uniform) but
    # can differ in chunk count; pad to the max so they stack to [P, ...].
    comm = dict(tx_src=None, tx_w=None, tx_segrel=None, tx_eid=None,
                tx_payload_slot=None, mx_pos=None, mx_dstrel=None,
                mx_valid=None)
    if comm_layout and layout == "ragged":
        per_shard = []
        for p in range(P):
            src_r, w_r, seg_r, eid_r, ct_r, S_pad = build_slot_ragged_layout(
                cut_rows_src[p], cut_rows_seg[p], cut_rows_w[p], S,
                sb=send_sb, eb=send_eb)
            per_shard.append((np.asarray(src_r), np.asarray(w_r),
                              np.asarray(seg_r), np.asarray(eid_r),
                              np.asarray(ct_r)))
        n_stiles = S_pad // send_sb
        tc = max(lay[0].shape[0] for lay in per_shard)
        tx_src = np.zeros((P, tc, send_eb), np.int64)
        tx_w = np.full((P, tc, send_eb), np.inf, np.float32)
        tx_segrel = np.zeros((P, tc, send_eb), np.int64)
        tx_eid = np.full((P, tc, send_eb), e_cut, np.int64)
        tx_ctile = np.full((P, tc), n_stiles, np.int64)
        for p, (src_r, w_r, seg_r, eid_r, ct_r) in enumerate(per_shard):
            nc = src_r.shape[0]
            tx_src[p, :nc] = src_r
            tx_w[p, :nc] = w_r
            tx_segrel[p, :nc] = seg_r
            # builder sentinel is the shard's own cut count; restamp to the
            # padded-row sentinel e_cut so the runtime gather is uniform
            eid = eid_r.astype(np.int64)
            eid[eid == len(cut_rows_src[p])] = e_cut
            tx_eid[p, :nc] = eid
            tx_ctile[p, :nc] = ct_r

        tx_payload_slot = np.full((P, P, C), S, np.int64)
        for p in range(P):
            owners, pos = slot_rows_owner[p], slot_pos_rows[p]
            tx_payload_slot[p, owners, pos] = np.arange(len(owners))

        mx_shards = [build_msg_ragged_layout(recv_idx[q], block, vb=merge_vb,
                                             eb=merge_eb) for q in range(P)]
        n_mtiles = mx_shards[0][4] // merge_vb
        mc = max(np.asarray(lay[0]).shape[0] for lay in mx_shards)
        mx_pos = np.zeros((P, mc, merge_eb), np.int64)
        mx_dstrel = np.zeros((P, mc, merge_eb), np.int64)
        mx_valid = np.zeros((P, mc, merge_eb), np.int64)
        mx_ctile = np.full((P, mc), n_mtiles, np.int64)
        for q, (pos_r, dr_r, v_r, ct_r, _bp) in enumerate(mx_shards):
            nc = np.asarray(pos_r).shape[0]
            mx_pos[q, :nc] = np.asarray(pos_r)
            mx_dstrel[q, :nc] = np.asarray(dr_r)
            mx_valid[q, :nc] = np.asarray(v_r)
            mx_ctile[q, :nc] = np.asarray(ct_r)

        comm = dict(tx_src=jnp.asarray(tx_src, jnp.int32),
                    tx_w=jnp.asarray(tx_w, jnp.float32),
                    tx_segrel=jnp.asarray(tx_segrel, jnp.int32),
                    tx_eid=jnp.asarray(tx_eid, jnp.int32),
                    tx_payload_slot=jnp.asarray(tx_payload_slot, jnp.int32),
                    tx_ctile=jnp.asarray(tx_ctile, jnp.int32),
                    mx_pos=jnp.asarray(mx_pos, jnp.int32),
                    mx_dstrel=jnp.asarray(mx_dstrel, jnp.int32),
                    mx_valid=jnp.asarray(mx_valid, jnp.int32),
                    mx_ctile=jnp.asarray(mx_ctile, jnp.int32))
    elif comm_layout:
        per_shard = []
        for p in range(P):
            src_t, w_t, seg_t, eid_t, _sp = build_slot_tiled_layout(
                cut_rows_src[p], cut_rows_seg[p], cut_rows_w[p], S,
                sb=send_sb, eb=send_eb)
            per_shard.append((np.asarray(src_t), np.asarray(w_t),
                              np.asarray(seg_t), np.asarray(eid_t)))
        n_stiles = per_shard[0][0].shape[0]
        n_chunks = max(lay[0].shape[1] for lay in per_shard)
        tx_src = np.zeros((P, n_stiles, n_chunks, send_eb), np.int64)
        tx_w = np.full((P, n_stiles, n_chunks, send_eb), np.inf, np.float32)
        tx_segrel = np.zeros((P, n_stiles, n_chunks, send_eb), np.int64)
        tx_eid = np.full((P, n_stiles, n_chunks, send_eb), e_cut, np.int64)
        for p, (src_t, w_t, seg_t, eid_t) in enumerate(per_shard):
            nc = src_t.shape[1]
            tx_src[p, :, :nc] = src_t
            tx_w[p, :, :nc] = w_t
            tx_segrel[p, :, :nc] = seg_t
            # builder sentinel is the shard's own cut count; restamp to the
            # padded-row sentinel e_cut so the runtime gather is uniform
            eid = eid_t.astype(np.int64)
            eid[eid == len(cut_rows_src[p])] = e_cut
            tx_eid[p, :, :nc] = eid

        # payload-position inverse: each (owner, pos) receives at most one
        # slot, so the runtime [P, C] payload scatter becomes a gather
        # (sentinel = S, out of the [0, S) slot range -> filled with +inf)
        tx_payload_slot = np.full((P, P, C), S, np.int64)
        for p in range(P):
            owners, pos = slot_rows_owner[p], slot_pos_rows[p]
            tx_payload_slot[p, owners, pos] = np.arange(len(owners))

        mx_shards = [build_msg_tiled_layout(recv_idx[q], block, vb=merge_vb,
                                            eb=merge_eb) for q in range(P)]
        n_mtiles = mx_shards[0][3] // merge_vb
        m_chunks = max(lay[0].shape[1] for lay in mx_shards)
        mx_pos = np.zeros((P, n_mtiles, m_chunks, merge_eb), np.int64)
        mx_dstrel = np.zeros((P, n_mtiles, m_chunks, merge_eb), np.int64)
        mx_valid = np.zeros((P, n_mtiles, m_chunks, merge_eb), np.int64)
        for q, (pos_t, dr_t, v_t, _bp) in enumerate(mx_shards):
            nc = pos_t.shape[1]
            mx_pos[q, :, :nc] = np.asarray(pos_t)
            mx_dstrel[q, :, :nc] = np.asarray(dr_t)
            mx_valid[q, :, :nc] = np.asarray(v_t)

        comm = dict(tx_src=jnp.asarray(tx_src, jnp.int32),
                    tx_w=jnp.asarray(tx_w, jnp.float32),
                    tx_segrel=jnp.asarray(tx_segrel, jnp.int32),
                    tx_eid=jnp.asarray(tx_eid, jnp.int32),
                    tx_payload_slot=jnp.asarray(tx_payload_slot, jnp.int32),
                    mx_pos=jnp.asarray(mx_pos, jnp.int32),
                    mx_dstrel=jnp.asarray(mx_dstrel, jnp.int32),
                    mx_valid=jnp.asarray(mx_valid, jnp.int32))

    return SsspShards(
        loc_src=jnp.asarray(_pad2(loc_rows_src, e_loc, block, np.int64), jnp.int32),
        loc_dst=jnp.asarray(_pad2(loc_rows_dst, e_loc, block, np.int64), jnp.int32),
        loc_w=jnp.asarray(_pad2(loc_rows_w, e_loc, np.inf, np.float32), jnp.float32),
        cut_src=jnp.asarray(_pad2(cut_rows_src, e_cut, block, np.int64), jnp.int32),
        cut_w=jnp.asarray(_pad2(cut_rows_w, e_cut, np.inf, np.float32), jnp.float32),
        cut_seg=jnp.asarray(_pad2(cut_rows_seg, e_cut, S, np.int64), jnp.int32),
        slot_owner=jnp.asarray(_pad2(slot_rows_owner, S, 0, np.int64), jnp.int32),
        slot_dstl=jnp.asarray(_pad2(slot_rows_dstl, S, 0, np.int64), jnp.int32),
        slot_pos=jnp.asarray(_pad2(slot_pos_rows, S, 0, np.int64), jnp.int32),
        slot_valid=jnp.asarray(_pad2([np.ones(len(r), bool) for r in slot_rows_owner], S, False, bool)),
        recv_idx=jnp.asarray(recv_idx, jnp.int32),
        tri_uj=jnp.asarray(tri_uj, jnp.int32),
        tri_ui=jnp.asarray(tri_ui, jnp.int32),
        tri_ij=jnp.asarray(tri_ij, jnp.int32),
        tri_valid=jnp.asarray(tri_valid),
        inter_edges=jnp.asarray(inter_edges, jnp.int32),
        n_vertices=n,
        n_parts=P,
        block=block,
        layout=layout,
        rx_vb=relax_vb,
        rx_eb=relax_eb,
        tx_sb=send_sb,
        tx_eb=send_eb,
        mx_vb=merge_vb,
        mx_eb=merge_eb,
        **rx,
        **comm,
    )
