"""Host-side shard preprocessing for SP-Async.

Splits each partition's edges into LOCAL (dst owned by the same shard) and
CUT (dst owned elsewhere) lists, and precomputes the *static message
routing* for the bucketed boundary exchange:

- Cut edges are grouped (host-side, one-time) by their boundary pair
  ``(dst_owner, dst_local)``. Each unique pair is a *message slot*.
- At runtime a shard segment-mins its cut-edge candidates into the slots
  (pre-aggregation: one message per boundary vertex, not per edge — the
  paper's future-work "message buffering" made static), scatters slots into
  a ``[P, C]`` send buffer at *precomputed static positions*, and fires one
  ``all_to_all``.
- The receive-side index table (which local vertex each incoming slot
  addresses) is also static: ``recv_idx[q, p, c]`` = the local vertex on
  shard q addressed by sender p's slot c. Built here by transposition.

Everything here is one-time host preprocessing — the paper's "Graph
Partition" phase. Besides the routing tables, three Pallas tile layouts
ride in the shards (each an instance of the same pre-tile-by-destination
pattern): ``rx_*`` (local edges by vertex tile, for the relax kernel),
``tx_*`` (cut edges by message-slot tile + the ``tx_payload_slot`` payload
inverse, for the send kernel), and ``mx_*`` (receive positions by vertex
tile, for the merge kernel).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.graph.structure import Graph
from repro.core.partition import partition_1d
from repro.kernels.merge import build_msg_tiled_layout
from repro.kernels.relax import build_dst_tiled_layout
from repro.kernels.send import build_slot_tiled_layout


def _pad2(rows, width, fill, dtype):
    out = np.full((len(rows), width), fill, dtype)
    for i, r in enumerate(rows):
        out[i, : len(r)] = r
    return out


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SsspShards:
    """All static per-shard state for the SP-Async solver, stacked [P, ...]."""

    # local edges (dst owned by this shard)
    loc_src: jax.Array     # [P, e_loc] int32 local ids
    loc_dst: jax.Array     # [P, e_loc] int32 local ids
    loc_w: jax.Array       # [P, e_loc] f32 (+inf padding)
    # cut edges (dst owned elsewhere), grouped by (owner, dst_local)
    cut_src: jax.Array     # [P, e_cut] int32 local ids
    cut_w: jax.Array       # [P, e_cut] f32 (+inf padding)
    cut_seg: jax.Array     # [P, e_cut] int32 -> slot segment id (S = padded)
    # message slots (unique boundary pairs)
    slot_owner: jax.Array  # [P, S] int32 destination shard
    slot_dstl: jax.Array   # [P, S] int32 dst-local id on the destination shard
    slot_pos: jax.Array    # [P, S] int32 position within the [P, C] send row
    slot_valid: jax.Array  # [P, S] bool
    # receive routing: local vertex addressed by (sender, bucket position)
    recv_idx: jax.Array    # [P, P, C] int32 (block = invalid sentinel)
    # Trishla triangle candidates: edge-id triples (uj to prune, ui, ij)
    tri_uj: jax.Array      # [P, T] int32 -> index into the *combined* edge view
    tri_ui: jax.Array      # [P, T] int32
    tri_ij: jax.Array      # [P, T] int32
    tri_valid: jax.Array   # [P, T] bool
    # ToKa1 bound inputs
    inter_edges: jax.Array  # [P] int32 per-shard cut-edge counts
    n_vertices: int = dataclasses.field(metadata=dict(static=True))
    n_parts: int = dataclasses.field(metadata=dict(static=True))
    block: int = dataclasses.field(metadata=dict(static=True))
    # dst-tiled layout of the LOCAL edges for the Pallas relax kernel
    # (built once at partition time; None when relax_layout=False). The
    # tiled slots are a permutation of [0, e_loc) plus padding; rx_eid maps
    # each slot back to its local edge id (sentinel = e_loc) so the runtime
    # Trishla pruned mask can be gathered into tiled order per solve.
    rx_src: jax.Array | None = None     # [P, n_vtiles, n_chunks, EB] int32
    rx_w: jax.Array | None = None       # [P, n_vtiles, n_chunks, EB] f32
    rx_dstrel: jax.Array | None = None  # [P, n_vtiles, n_chunks, EB] int32
    rx_eid: jax.Array | None = None     # [P, n_vtiles, n_chunks, EB] int32
    rx_vb: int = dataclasses.field(default=128, metadata=dict(static=True))
    rx_eb: int = dataclasses.field(default=512, metadata=dict(static=True))
    # slot-tiled layout of the CUT edges for the Pallas send kernel (same
    # dst-tiled pattern with the message SLOT in the destination role;
    # None when comm_layout=False). tx_eid maps tiled slots back to cut
    # edge ids (sentinel = e_cut) for the runtime Trishla pruned gather.
    tx_src: jax.Array | None = None     # [P, n_stiles, n_chunks, EB] int32
    tx_w: jax.Array | None = None       # [P, n_stiles, n_chunks, EB] f32
    tx_segrel: jax.Array | None = None  # [P, n_stiles, n_chunks, EB] int32
    tx_eid: jax.Array | None = None     # [P, n_stiles, n_chunks, EB] int32
    # static inverse of (slot_owner, slot_pos): the slot feeding each
    # bucketed payload position, so the payload scatter becomes a gather
    tx_payload_slot: jax.Array | None = None  # [P, P, C] int32 (sentinel = S)
    tx_sb: int = dataclasses.field(default=128, metadata=dict(static=True))
    tx_eb: int = dataclasses.field(default=512, metadata=dict(static=True))
    # msg-tiled receive routing for the Pallas merge kernel: flat incoming
    # positions [0, P*C) grouped by destination vertex tile
    mx_pos: jax.Array | None = None     # [P, n_vtiles, n_chunks, EB] int32
    mx_dstrel: jax.Array | None = None  # [P, n_vtiles, n_chunks, EB] int32
    mx_valid: jax.Array | None = None   # [P, n_vtiles, n_chunks, EB] int32
    mx_vb: int = dataclasses.field(default=128, metadata=dict(static=True))
    mx_eb: int = dataclasses.field(default=512, metadata=dict(static=True))

    @property
    def e_loc(self):
        return self.loc_src.shape[1]

    @property
    def e_cut(self):
        return self.cut_src.shape[1]

    @property
    def n_slots(self):
        return self.slot_owner.shape[1]

    @property
    def bucket_cap(self):
        return self.recv_idx.shape[2]

    @property
    def has_relax_layout(self):
        return self.rx_src is not None

    @property
    def relax_layout(self):
        """Per-call tuple consumed by ``local_fixpoint_batch`` (or None)."""
        if self.rx_src is None:
            return None
        return (self.rx_src, self.rx_w, self.rx_dstrel, self.rx_eid)

    @property
    def has_send_layout(self):
        return self.tx_src is not None

    @property
    def send_layout(self):
        """Per-call tuple consumed by the pallas send stage (or None)."""
        if self.tx_src is None:
            return None
        return (self.tx_src, self.tx_w, self.tx_segrel, self.tx_eid)

    @property
    def has_merge_layout(self):
        return self.mx_pos is not None

    @property
    def merge_layout(self):
        """Per-call tuple consumed by the pallas merge stage (or None)."""
        if self.mx_pos is None:
            return None
        return (self.mx_pos, self.mx_dstrel, self.mx_valid)


def shard_distance_rows(rows, n_parts: int, block: int) -> jax.Array:
    """Re-shard host distance rows into the carry's per-shard layout.

    ``rows``: [L, n_vertices] (e.g. the L solved landmark sources) ->
    ``[P, L, block]`` with +inf on the padding vertices, matching how the
    solver's ``dist`` is blocked across shards. This is the storage layout
    of the engine's landmark cache — 4 B x L x block per shard — chosen so
    the warm-init seed is a per-shard broadcast against the resident
    ``dist`` block, with no runtime re-partitioning."""
    rows = np.asarray(rows, np.float32)
    n_land, n = rows.shape
    full = np.full((n_land, n_parts * block), np.inf, np.float32)
    full[:, :n] = rows
    return jnp.asarray(np.swapaxes(full.reshape(n_land, n_parts, block), 0, 1))


def build_shards(g: Graph, n_parts: int, max_triangles_per_part: int | None = None,
                 enumerate_triangles: bool = True, relax_layout: bool = True,
                 relax_vb: int = 128, relax_eb: int = 512,
                 comm_layout: bool = True, send_sb: int = 128,
                 send_eb: int = 512, merge_vb: int = 128,
                 merge_eb: int = 512) -> SsspShards:
    # input hardening: a NaN weight propagates through every min it
    # touches, and a negative weight breaks the monotonicity the whole
    # async pipeline (and its termination proofs) rests on — both would
    # otherwise surface only as silently wrong fixpoints. Padding edges
    # legitimately carry +inf, so only the graph's valid edges are checked.
    w_all = np.asarray(g.weight)
    v_all = np.asarray(g.valid)
    bad_nan = v_all & np.isnan(w_all)
    bad_inf = v_all & ~np.isnan(w_all) & ~np.isfinite(w_all)
    bad_neg = v_all & (w_all < 0)
    if bad_nan.any() or bad_inf.any() or bad_neg.any():
        raise ValueError(
            f"invalid edge weights: {int(bad_nan.sum())} NaN, "
            f"{int(bad_inf.sum())} non-finite, {int(bad_neg.sum())} "
            "negative — SSSP requires finite non-negative weights")
    pg = partition_1d(g, n_parts)
    P, block, n = pg.n_parts, pg.block, pg.n_vertices

    src_l = np.asarray(pg.src_local)
    dst_o = np.asarray(pg.dst_owner)
    dst_l = np.asarray(pg.dst_local)
    w = np.asarray(pg.weight)
    valid = np.asarray(pg.valid)
    is_cut = np.asarray(pg.is_cut)

    loc_rows_src, loc_rows_dst, loc_rows_w = [], [], []
    cut_rows_src, cut_rows_w, cut_rows_seg = [], [], []
    slot_rows_owner, slot_rows_dstl = [], []
    inter_edges = np.zeros(P, np.int64)

    for p in range(P):
        lm = valid[p] & ~is_cut[p]
        cm = valid[p] & is_cut[p]
        loc_rows_src.append(src_l[p][lm])
        loc_rows_dst.append(dst_l[p][lm])
        loc_rows_w.append(w[p][lm])
        # group cut edges by (owner, dst_local)
        co, cl, cs, cw = dst_o[p][cm], dst_l[p][cm], src_l[p][cm], w[p][cm]
        order = np.lexsort((cl, co))
        co, cl, cs, cw = co[order], cl[order], cs[order], cw[order]
        key = co.astype(np.int64) * block + cl
        if len(key):
            new_seg = np.ones(len(key), bool)
            new_seg[1:] = key[1:] != key[:-1]
            seg_id = np.cumsum(new_seg) - 1
            u_owner = co[new_seg]
            u_dstl = cl[new_seg]
        else:
            seg_id = np.zeros(0, np.int64)
            u_owner = np.zeros(0, np.int64)
            u_dstl = np.zeros(0, np.int64)
        cut_rows_src.append(cs)
        cut_rows_w.append(cw)
        cut_rows_seg.append(seg_id)
        slot_rows_owner.append(u_owner)
        slot_rows_dstl.append(u_dstl)
        inter_edges[p] = int(cm.sum())

    e_loc = max(max((len(r) for r in loc_rows_src), default=0), 1)
    e_cut = max(max((len(r) for r in cut_rows_src), default=0), 1)
    S = max(max((len(r) for r in slot_rows_owner), default=0), 1)

    # position of each slot within its destination bucket row
    slot_pos_rows = []
    cap = 1
    for p in range(P):
        owners = slot_rows_owner[p]
        pos = np.zeros(len(owners), np.int64)
        for q in np.unique(owners):
            m = owners == q
            pos[m] = np.arange(m.sum())
            cap = max(cap, int(m.sum()))
        slot_pos_rows.append(pos)
    C = cap

    # receive routing table: recv_idx[q, p, c] = dst_local, built by transpose
    recv_idx = np.full((P, P, C), block, np.int64)
    for p in range(P):
        owners, dstl, pos = slot_rows_owner[p], slot_rows_dstl[p], slot_pos_rows[p]
        recv_idx[owners, p, pos] = dstl

    # ---- Trishla triangle candidates (host-side enumeration) --------------
    # Combined per-shard edge view: local edges [0, e_loc) then cut edges
    # [e_loc, e_loc + e_cut). Triangles (u, vi, vj): u and vi owned by this
    # shard (so (vi, vj) is visible), vj arbitrary, both (u, vi), (u, vj),
    # (vi, vj) present. Candidate to prune: (u, vj).
    tri_rows = [[] for _ in range(P)]
    if enumerate_triangles:
        # per-shard edge lookup: (src_local, dst_global) -> combined edge id
        for p in range(P):
            lsrc, ldst, lw = loc_rows_src[p], loc_rows_dst[p], loc_rows_w[p]
            csrc, cw_, cseg = cut_rows_src[p], cut_rows_w[p], cut_rows_seg[p]
            # global dst of cut edges: owner*block + dst_local via slots
            cg = (slot_rows_owner[p][cseg] * block + slot_rows_dstl[p][cseg]) if len(cseg) else np.zeros(0, np.int64)
            all_src = np.concatenate([lsrc, csrc])            # local u ids
            all_dstg = np.concatenate([ldst + p * block, cg]) # global v ids
            # edge ids must match the runtime combined view, where local
            # edges are PADDED to e_loc before the cut edges are appended
            eid = np.concatenate([np.arange(len(lsrc)),
                                  e_loc + np.arange(len(csrc))])
            # adjacency (by local src) for this shard
            order = np.argsort(all_src, kind="stable")
            s_srt, d_srt, e_srt = all_src[order], all_dstg[order], eid[order]
            starts = np.searchsorted(s_srt, np.arange(block + 1))
            budget = max_triangles_per_part
            tri = tri_rows[p]
            for u in range(block):
                lo, hi = starts[u], starts[u + 1]
                if hi - lo < 2:
                    continue
                nbrs = d_srt[lo:hi]
                nbr_eids = e_srt[lo:hi]
                for a in range(len(nbrs)):
                    vi = nbrs[a]
                    if vi // block != p:
                        continue  # (vi, vj) must be visible: vi owned here
                    vi_loc = vi - p * block
                    vlo, vhi = starts[vi_loc], starts[vi_loc + 1]
                    vi_out = d_srt[vlo:vhi]
                    vi_out_eids = e_srt[vlo:vhi]
                    # intersect N(u) and N(vi)
                    common, ia, ib = np.intersect1d(nbrs, vi_out, return_indices=True)
                    for t in range(len(common)):
                        vj = common[t]
                        if vj == u + p * block or vj == vi:
                            continue
                        tri.append((nbr_eids[ia[t]], nbr_eids[a], vi_out_eids[ib[t]]))
                        if budget is not None and len(tri) >= budget:
                            break
                    if budget is not None and len(tri) >= budget:
                        break
                if budget is not None and len(tri) >= budget:
                    break
    T = max(max((len(r) for r in tri_rows), default=0), 1)
    tri_uj = np.full((P, T), 0, np.int64)
    tri_ui = np.full((P, T), 0, np.int64)
    tri_ij = np.full((P, T), 0, np.int64)
    tri_valid = np.zeros((P, T), bool)
    for p in range(P):
        for k, (a, b, c) in enumerate(tri_rows[p]):
            tri_uj[p, k], tri_ui[p, k], tri_ij[p, k] = a, b, c
            tri_valid[p, k] = True

    # ---- dst-tiled layout of the local edges (Pallas relax kernel) --------
    # Built once here — NOT per solve. Per-shard layouts share n_vtiles
    # (same block) but can differ in chunk count; pad to the max so they
    # stack into one [P, n_vtiles, n_chunks, EB] array for the sim backend
    # (the shard_map backend slices its own shard back out).
    rx = dict(rx_src=None, rx_w=None, rx_dstrel=None, rx_eid=None)
    if relax_layout:
        per_shard = []
        for p in range(P):
            src_t, w_t, dr_t, eid_t, _bp = build_dst_tiled_layout(
                loc_rows_src[p], loc_rows_dst[p], loc_rows_w[p], block,
                vb=relax_vb, eb=relax_eb, with_eid=True)
            per_shard.append((np.asarray(src_t), np.asarray(w_t),
                              np.asarray(dr_t), np.asarray(eid_t)))
        n_vtiles = per_shard[0][0].shape[0]
        block_pad = n_vtiles * relax_vb
        n_chunks = max(lay[0].shape[1] for lay in per_shard)
        rx_src = np.full((P, n_vtiles, n_chunks, relax_eb), block_pad - 1,
                         np.int64)
        rx_w = np.full((P, n_vtiles, n_chunks, relax_eb), np.inf, np.float32)
        rx_dstrel = np.zeros((P, n_vtiles, n_chunks, relax_eb), np.int64)
        rx_eid = np.full((P, n_vtiles, n_chunks, relax_eb), e_loc, np.int64)
        for p, (src_t, w_t, dr_t, eid_t) in enumerate(per_shard):
            nc = src_t.shape[1]
            rx_src[p, :, :nc] = src_t
            rx_w[p, :, :nc] = w_t
            rx_dstrel[p, :, :nc] = dr_t
            # builder sentinel is the shard's own edge count; restamp to the
            # padded-row sentinel e_loc so the runtime gather is uniform
            eid = eid_t.astype(np.int64)
            eid[eid == len(loc_rows_src[p])] = e_loc
            rx_eid[p, :, :nc] = eid
        rx = dict(rx_src=jnp.asarray(rx_src, jnp.int32),
                  rx_w=jnp.asarray(rx_w, jnp.float32),
                  rx_dstrel=jnp.asarray(rx_dstrel, jnp.int32),
                  rx_eid=jnp.asarray(rx_eid, jnp.int32))

    # ---- slot/msg-tiled layouts for the Pallas send + merge kernels -------
    # Same one-time host build as rx_*: per-shard layouts share the tile
    # count (slots padded to S / vertices to block are shard-uniform) but
    # can differ in chunk count; pad to the max so they stack to [P, ...].
    comm = dict(tx_src=None, tx_w=None, tx_segrel=None, tx_eid=None,
                tx_payload_slot=None, mx_pos=None, mx_dstrel=None,
                mx_valid=None)
    if comm_layout:
        per_shard = []
        for p in range(P):
            src_t, w_t, seg_t, eid_t, _sp = build_slot_tiled_layout(
                cut_rows_src[p], cut_rows_seg[p], cut_rows_w[p], S,
                sb=send_sb, eb=send_eb)
            per_shard.append((np.asarray(src_t), np.asarray(w_t),
                              np.asarray(seg_t), np.asarray(eid_t)))
        n_stiles = per_shard[0][0].shape[0]
        n_chunks = max(lay[0].shape[1] for lay in per_shard)
        tx_src = np.zeros((P, n_stiles, n_chunks, send_eb), np.int64)
        tx_w = np.full((P, n_stiles, n_chunks, send_eb), np.inf, np.float32)
        tx_segrel = np.zeros((P, n_stiles, n_chunks, send_eb), np.int64)
        tx_eid = np.full((P, n_stiles, n_chunks, send_eb), e_cut, np.int64)
        for p, (src_t, w_t, seg_t, eid_t) in enumerate(per_shard):
            nc = src_t.shape[1]
            tx_src[p, :, :nc] = src_t
            tx_w[p, :, :nc] = w_t
            tx_segrel[p, :, :nc] = seg_t
            # builder sentinel is the shard's own cut count; restamp to the
            # padded-row sentinel e_cut so the runtime gather is uniform
            eid = eid_t.astype(np.int64)
            eid[eid == len(cut_rows_src[p])] = e_cut
            tx_eid[p, :, :nc] = eid

        # payload-position inverse: each (owner, pos) receives at most one
        # slot, so the runtime [P, C] payload scatter becomes a gather
        # (sentinel = S, out of the [0, S) slot range -> filled with +inf)
        tx_payload_slot = np.full((P, P, C), S, np.int64)
        for p in range(P):
            owners, pos = slot_rows_owner[p], slot_pos_rows[p]
            tx_payload_slot[p, owners, pos] = np.arange(len(owners))

        mx_shards = [build_msg_tiled_layout(recv_idx[q], block, vb=merge_vb,
                                            eb=merge_eb) for q in range(P)]
        n_mtiles = mx_shards[0][3] // merge_vb
        m_chunks = max(lay[0].shape[1] for lay in mx_shards)
        mx_pos = np.zeros((P, n_mtiles, m_chunks, merge_eb), np.int64)
        mx_dstrel = np.zeros((P, n_mtiles, m_chunks, merge_eb), np.int64)
        mx_valid = np.zeros((P, n_mtiles, m_chunks, merge_eb), np.int64)
        for q, (pos_t, dr_t, v_t, _bp) in enumerate(mx_shards):
            nc = pos_t.shape[1]
            mx_pos[q, :, :nc] = np.asarray(pos_t)
            mx_dstrel[q, :, :nc] = np.asarray(dr_t)
            mx_valid[q, :, :nc] = np.asarray(v_t)

        comm = dict(tx_src=jnp.asarray(tx_src, jnp.int32),
                    tx_w=jnp.asarray(tx_w, jnp.float32),
                    tx_segrel=jnp.asarray(tx_segrel, jnp.int32),
                    tx_eid=jnp.asarray(tx_eid, jnp.int32),
                    tx_payload_slot=jnp.asarray(tx_payload_slot, jnp.int32),
                    mx_pos=jnp.asarray(mx_pos, jnp.int32),
                    mx_dstrel=jnp.asarray(mx_dstrel, jnp.int32),
                    mx_valid=jnp.asarray(mx_valid, jnp.int32))

    return SsspShards(
        loc_src=jnp.asarray(_pad2(loc_rows_src, e_loc, block, np.int64), jnp.int32),
        loc_dst=jnp.asarray(_pad2(loc_rows_dst, e_loc, block, np.int64), jnp.int32),
        loc_w=jnp.asarray(_pad2(loc_rows_w, e_loc, np.inf, np.float32), jnp.float32),
        cut_src=jnp.asarray(_pad2(cut_rows_src, e_cut, block, np.int64), jnp.int32),
        cut_w=jnp.asarray(_pad2(cut_rows_w, e_cut, np.inf, np.float32), jnp.float32),
        cut_seg=jnp.asarray(_pad2(cut_rows_seg, e_cut, S, np.int64), jnp.int32),
        slot_owner=jnp.asarray(_pad2(slot_rows_owner, S, 0, np.int64), jnp.int32),
        slot_dstl=jnp.asarray(_pad2(slot_rows_dstl, S, 0, np.int64), jnp.int32),
        slot_pos=jnp.asarray(_pad2(slot_pos_rows, S, 0, np.int64), jnp.int32),
        slot_valid=jnp.asarray(_pad2([np.ones(len(r), bool) for r in slot_rows_owner], S, False, bool)),
        recv_idx=jnp.asarray(recv_idx, jnp.int32),
        tri_uj=jnp.asarray(tri_uj, jnp.int32),
        tri_ui=jnp.asarray(tri_ui, jnp.int32),
        tri_ij=jnp.asarray(tri_ij, jnp.int32),
        tri_valid=jnp.asarray(tri_valid),
        inter_edges=jnp.asarray(inter_edges, jnp.int32),
        n_vertices=n,
        n_parts=P,
        block=block,
        rx_vb=relax_vb,
        rx_eb=relax_eb,
        tx_sb=send_sb,
        tx_eb=send_eb,
        mx_vb=merge_vb,
        mx_eb=merge_eb,
        **rx,
        **comm,
    )
