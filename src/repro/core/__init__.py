from repro.core.sssp import (RoundPipeline, SsspConfig, SsspStats,
                             build_pipeline, build_shmap_certificate,
                             build_shmap_solver, build_shmap_solver_traced,
                             certificate_improved_sim, sim_phase_fns,
                             solve_shmap, solve_shmap_batch, solve_sim,
                             solve_sim_batch)
from repro.core.engine import (QueryHandle, QueryResult, SsspEngine,
                               bucket_k, engine_for)
from repro.core.faults import FaultPlan, FaultState, wrap_exchange
from repro.core.shards import (SsspShards, build_shards, build_shards_stream,
                               shard_distance_rows)
from repro.core.warmstart import CachedRow, LandmarkCache, ResultCache
from repro.core.partition import partition_1d, inter_edge_counts
from repro.core import phases
