from repro.core.sssp import (SsspConfig, SsspStats, build_shmap_solver,
                             solve_shmap, solve_shmap_batch, solve_sim,
                             solve_sim_batch)
from repro.core.shards import SsspShards, build_shards
from repro.core.partition import partition_1d, inter_edge_counts
