from repro.core.sssp import SsspConfig, SsspStats, solve_sim, solve_shmap, build_shmap_solver
from repro.core.shards import SsspShards, build_shards
from repro.core.partition import partition_1d, inter_edge_counts
