"""Warm-start subsystem: landmark distance cache + query-result reuse.

The serving ROADMAP amortizes one ``build_shards`` over many queries; this
module amortizes the *solves* themselves — repeated and nearby sources
should not pay full Bellman rounds. Two cache layers, both owned by
:class:`~repro.core.engine.SsspEngine`:

1. **Landmark cache** (:class:`LandmarkCache`): L pivot sources are solved
   once and their distances stored SHARDED, ``[P, L, block]`` — the same
   layout as the carry's ``dist``, so the seed computation is a per-shard
   broadcast with no re-partitioning. A traced ``warm_init`` stage then
   seeds every query's distance vector with the triangle-inequality upper
   bound ``min_l(land[l, src] + land[l, v])`` instead of ``+inf``
   (heuristic-search SSSP, arXiv:2506.19349: landmark upper bounds prune
   most relaxations). Every seeded vertex starts ACTIVE, so the first
   round relaxes from the whole seeded set and later rounds only propagate
   residual corrections — the monotone scatter-min pipeline converges in
   fewer rounds with bit-identical final distances (the seed is an upper
   bound; relaxation from any upper-bound initialization reaches the same
   fixpoint it reaches from the cold ``+inf`` start).

   The bound assumes symmetric distances (``d(src, l) == d(l, src)``) —
   true for every undirected generator in :mod:`repro.graph.generators`.
   Memory: ``4 B x L x block`` per shard, the cost model documented in
   ROADMAP.md.

2. **Query-result cache**: an LRU keyed by ``(source, graph_epoch)``
   serving exact repeats without a solve — zero rounds, the stored
   distance row returned as-is (SSSP-Del, arXiv:2508.14319: cached
   distances are state that survives across queries, not per-call
   scratch). The engine strips cached sources from a batch BEFORE bucket
   padding, so a mixed batch rides a smaller compiled bucket; ``drain``
   coalescing inherits this for free. The epoch key is the invalidation
   hook: bumping ``engine.graph_epoch`` orphans every cached row (and the
   landmark cache) without a scan.

The ``warm_init`` phase registers here (backends ``none | landmark``) so
``SsspConfig`` validates ``cfg.warm_start`` eagerly like every other phase
backend. This module stays dependency-light (phases + jax) so both the
engine and the sssp driver may import it.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import phases

INF = jnp.float32(jnp.inf)

# Relative inflation applied to every triangle-inequality bound whose
# landmark-to-source leg is nonzero. Float addition is non-associative, so
# the two-leg sum ``land[l, src] + land[l, v]`` can land a few ULPs BELOW
# the value the cold solve derives by relaxing edge-by-edge along the same
# path — and the monotone pipeline would then keep the seed, breaking
# bit-identity with the cold solve (observed: 1-ULP undershoots on the
# road grid). Inflating by ~1.7e3 ULPs keeps the seed >= the cold fixpoint
# for any realistic path length while costing a vanishing fraction of the
# bound's pruning power. The ``land[l, src] == 0`` row (the source IS
# landmark l — nothing else is at distance 0 with >= 1 weights) is NOT
# inflated: ``0 + land[l, v]`` is bit-exactly that pivot's solved
# fixpoint, which is what lets an exactly-repeated source converge in one
# round instead of re-propagating the whole wave.
WARM_EPS = jnp.float32(1.0 + 1e-4)


# --------------------------------------------------------------------------
# landmark cache
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LandmarkCache:
    """L solved pivot sources, distances stored sharded like the carry.

    ``dist[p, l, v]`` = distance from landmark ``l`` to local vertex ``v``
    of shard ``p`` (``+inf`` where unreachable / padding). ``epoch`` ties
    the cache to the graph state it was computed against."""

    sources: tuple          # the L landmark source ids
    dist: jax.Array         # [P, L, block] f32
    epoch: int              # graph epoch this cache is valid for

    @property
    def n_landmarks(self) -> int:
        return self.dist.shape[1]

    @property
    def nbytes_per_shard(self) -> int:
        """The documented cost model: 4 B x L x block per shard."""
        return 4 * self.dist.shape[1] * self.dist.shape[2]

    def __repr__(self):
        return (f"LandmarkCache(L={self.n_landmarks}, "
                f"sources={self.sources}, epoch={self.epoch}, "
                f"{self.nbytes_per_shard}B/shard)")


def landmark_seed_stacked(land, sources, q_valid):
    """Warm seed over the stacked sim representation.

    ``land``: [P, L, block]; ``sources``: [K] int32 (traced); ``q_valid``:
    [K] bool. Returns seed dist [P, K, block] =
    ``min_l(land[l, src_k] + land[p, l, v])`` — +inf for invalid (padded)
    queries, so they initialize exactly like the cold path."""
    n_parts, n_land, block = land.shape
    flat = jnp.swapaxes(land, 0, 1).reshape(n_land, n_parts * block)
    at_src = flat[:, sources]                                   # [L, K]

    def body(l, acc):
        bound = at_src[l][None, :, None] + land[:, l][:, None, :]
        bound = jnp.where(at_src[l][None, :, None] == 0.0, bound,
                          bound * WARM_EPS)
        return jnp.minimum(acc, bound)

    seed = jax.lax.fori_loop(
        0, n_land, body,
        jnp.full((n_parts, sources.shape[0], block), INF, jnp.float32))
    return jnp.where(q_valid[None, :, None], seed, INF)


def landmark_seed_shard(land_loc, sources, q_valid, rank, block, min_all):
    """Warm seed inside a shard_map body.

    ``land_loc``: THIS shard's [L, block] landmark distances. The
    landmark-at-source gather needs the owner shard's value, so each shard
    contributes ``land[l, src_k]`` where it owns ``src_k`` (+inf
    elsewhere) and ``min_all`` (an all-reduce min over the mesh — ONE
    small [L, K] collective) replicates the result. Returns [K, block]."""
    owner = sources // block
    local = sources % block
    mine = (owner == rank) & q_valid                            # [K]
    contrib = jnp.where(mine[None, :], land_loc[:, local], INF)  # [L, K]
    at_src = min_all(contrib)                                   # [L, K]
    n_land = land_loc.shape[0]

    def body(l, acc):
        bound = at_src[l][:, None] + land_loc[l][None, :]
        bound = jnp.where(at_src[l][:, None] == 0.0, bound, bound * WARM_EPS)
        return jnp.minimum(acc, bound)

    seed = jax.lax.fori_loop(
        0, n_land, body,
        jnp.full((sources.shape[0], land_loc.shape[1]), INF, jnp.float32))
    return jnp.where(q_valid[:, None], seed, INF)


# --------------------------------------------------------------------------
# warm_init phase registry (config key: cfg.warm_start)
# --------------------------------------------------------------------------

class WarmInitStage(NamedTuple):
    """Registry entry for a warm-init backend. ``needs_landmarks`` gates
    the engine-side cache requirement; ``seed_stacked`` / ``seed_shard``
    produce the traced seed-dist input ``_init_carry`` consumes (``None``
    backends keep the cold +inf initialization)."""
    name: str
    needs_landmarks: bool
    seed_stacked: Any   # (land, sources, q_valid) -> [P, K, block] | None
    seed_shard: Any     # (land_loc, sources, q_valid, rank, block, min_all)


phases.register("warm_init", "none")(WarmInitStage(
    "none", needs_landmarks=False, seed_stacked=None, seed_shard=None))
phases.register("warm_init", "landmark")(WarmInitStage(
    "landmark", needs_landmarks=True, seed_stacked=landmark_seed_stacked,
    seed_shard=landmark_seed_shard))


# --------------------------------------------------------------------------
# query-result LRU
# --------------------------------------------------------------------------

class CachedRow(NamedTuple):
    """One solved query kept across calls: the full distance row. A cache
    hit reports zero rounds/relaxations (THIS call did no work), so no
    counters ride along."""
    dist: np.ndarray        # [n_vertices] f32


class ResultCache:
    """Tiny LRU over solved (source, graph_epoch) rows.

    ``get`` refreshes recency; ``put`` evicts the least-recently-used row
    once ``maxsize`` is exceeded. ``maxsize == 0`` disables the cache
    (every lookup misses, nothing is stored) so the engine's default
    behavior is bit-for-bit the uncached path."""

    def __init__(self, maxsize: int):
        self.maxsize = int(maxsize)
        self._rows: OrderedDict[tuple, CachedRow] = OrderedDict()
        self.hits = 0
        self.misses = 0

    def __len__(self):
        return len(self._rows)

    def get(self, source: int, epoch: int) -> CachedRow | None:
        if self.maxsize == 0:
            return None
        row = self._rows.get((source, epoch))
        if row is None:
            self.misses += 1
            return None
        self._rows.move_to_end((source, epoch))
        self.hits += 1
        return row

    def put(self, source: int, epoch: int, row: CachedRow) -> None:
        if self.maxsize == 0:
            return
        self._rows[(source, epoch)] = row
        self._rows.move_to_end((source, epoch))
        while len(self._rows) > self.maxsize:
            self._rows.popitem(last=False)

    def clear(self) -> None:
        self._rows.clear()
